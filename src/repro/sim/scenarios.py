"""Named attack scenarios over the MSF plant — the fleet workload library.

The §7 dataset exercises seven attack families one at a time on one canned
plant.  Fleet-scale serving needs a *heterogeneous* workload: this module
composes the families into named scenarios (family x onset x intensity x
duration, plus multi-attack sequences) and adds per-plant physical-parameter
jitter, so a fleet of :class:`~repro.sim.msf.PlantStream` instances exercises
the detector on plants that differ in dynamics, attack timing and magnitude.

Scenario semantics: events are scheduled in absolute scan cycles; when events
overlap the earliest-listed one wins (one adversary at the controls at a
time).  Jitter perturbs the plant's *physical* constants (thermal time
constant, steam/flash gains, noise floors) — never the Wd setpoint, which the
operator fixes fleet-wide — so normal operation stays near the nominal point
the detector was calibrated on while transients differ per plant.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sim.msf import (ATTACK_NAMES, AttackEvent, ParamDrift, PlantParams,
                           PlantStream, jitter_params)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, reproducible attack schedule for one plant.

    ``drift`` optionally creeps the plant's physical constants over the run
    (:class:`~repro.sim.msf.ParamDrift`) — benign, so a drift-only scenario
    has no onset and its verdict stream counts toward false-positive rate,
    not detection."""

    name: str
    description: str
    events: Tuple[AttackEvent, ...] = ()
    jitter: float = 0.01          # relative physical-parameter jitter
    drift: Optional[ParamDrift] = None

    @property
    def families(self) -> Tuple[int, ...]:
        return tuple(sorted({e.attack_id for e in self.events}))

    @property
    def composed(self) -> bool:
        return len(self.events) >= 2

    @property
    def onset(self) -> Optional[int]:
        """First attacked cycle (None for a benign scenario)."""
        return min((e.start for e in self.events), default=None)


def _s(name: str, description: str, *events: AttackEvent,
       jitter: float = 0.01, drift: Optional[ParamDrift] = None) -> Scenario:
    return Scenario(name=name, description=description, events=tuple(events),
                    jitter=jitter, drift=drift)


# One scenario per family at §7 magnitudes, plus intensity/duration variants
# and composed multi-attack sequences.  Onsets leave ≥1 full detector window
# (200 cycles) of normal operation first.
_ALL = [
    _s("baseline", "benign operation, jittered plant"),
    _s("steam-throttle", "steam valve scaled down (family 1)",
       AttackEvent(1, start=400)),
    _s("recycle-starve", "recycle brine flow cut (family 2)",
       AttackEvent(2, start=400)),
    _s("reject-flood", "water rejection forced up (family 3)",
       AttackEvent(3, start=400)),
    _s("tb0-spoof", "TB0 sensor false-data injection (family 4)",
       AttackEvent(4, start=400)),
    _s("wd-spoof", "Wd sensor false-data injection (family 5)",
       AttackEvent(5, start=400)),
    _s("valve-flutter", "oscillatory steam valve (family 6)",
       AttackEvent(6, start=400)),
    _s("stealth-drift", "slow recycle-efficiency ramp (family 7)",
       AttackEvent(7, start=300)),
    _s("steam-pulse", "short, hard steam throttle burst",
       AttackEvent(1, start=400, duration=200, intensity=1.5)),
    _s("gentle-starve", "low-intensity recycle cut (stealthier family 2)",
       AttackEvent(2, start=500, intensity=0.5)),
    _s("spoof-then-starve", "TB0 spoof burst, then a recycle cut",
       AttackEvent(4, start=300, duration=300),
       AttackEvent(2, start=800)),
    _s("flutter-then-throttle", "valve flutter probing, then a throttle",
       AttackEvent(6, start=300, duration=400, intensity=0.8),
       AttackEvent(1, start=900)),
    _s("drift-then-spoof", "stealth ramp handing off to a Wd spoof",
       AttackEvent(7, start=200, duration=600),
       AttackEvent(5, start=900)),
    _s("full-gauntlet", "three families back to back with recovery gaps",
       AttackEvent(1, start=300, duration=200),
       AttackEvent(3, start=700, duration=200),
       AttackEvent(5, start=1100, duration=200)),
    # Drifting plants (time-varying physical constants, NOT attacks): the
    # flash-gain decay moves the PID-held TB0 operating point by ~2 sigma of
    # the detector normalization — the benign-score creep that floods a
    # fixed threshold and that streaming recalibration must absorb.
    _s("seasonal-drift",
       "benign flash-gain decay + warming seawater; no attack",
       drift=ParamDrift({"k_flash": -0.08, "t_sea": 0.04},
                        start=300, ramp=1200)),
    _s("drift-then-throttle",
       "steam throttle landing on an already-drifted plant",
       AttackEvent(1, start=1300),
       drift=ParamDrift({"k_flash": -0.08}, start=300, ramp=800)),
]

SCENARIOS: Dict[str, Scenario] = {s.name: s for s in _ALL}
assert len(SCENARIOS) == len(_ALL), "duplicate scenario name"
_BUILTIN = frozenset(SCENARIOS)     # the library core, never unregistrable


def list_scenarios() -> List[str]:
    return list(SCENARIOS)


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; known: {', '.join(SCENARIOS)}")


def register_scenario(scenario: Scenario) -> Scenario:
    """Add a user-defined scenario to the library (name must be fresh).

    Registration mutates the process-global ``SCENARIOS`` dict; pair it
    with :func:`unregister_scenario`, or use the :func:`registered` context
    manager so the entry cannot leak across tests and sessions.
    """
    if scenario.name in SCENARIOS:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    SCENARIOS[scenario.name] = scenario
    return scenario


def unregister_scenario(name: str) -> Scenario:
    """Remove a previously registered scenario and return it.

    Built-in library scenarios are protected — the fleet builders and the
    example CLI assume they exist for the life of the process.
    """
    if name in _BUILTIN:
        raise ValueError(f"scenario {name!r} is a built-in library scenario "
                         "and cannot be unregistered")
    try:
        return SCENARIOS.pop(name)
    except KeyError:
        raise KeyError(
            f"scenario {name!r} is not registered; known: "
            f"{', '.join(SCENARIOS)}")


@contextlib.contextmanager
def registered(*scenarios: Scenario):
    """Scoped registration: the scenarios exist inside the ``with`` block
    and are removed on exit — even on error, and even if the block itself
    already unregistered some of them.  The sanctioned way for tests and
    ad-hoc drivers to extend the library without leaking global state."""
    added: List[str] = []
    try:
        for sc in scenarios:
            register_scenario(sc)
            added.append(sc.name)
        yield scenarios[0] if len(scenarios) == 1 else scenarios
    finally:
        for name in added:
            SCENARIOS.pop(name, None)


def build_fleet(
    names: Optional[Sequence[str]] = None,
    n_plants: Optional[int] = None,
    *,
    seed: int = 0,
    jitter: Optional[float] = None,
    base_params: Optional[PlantParams] = None,
    drift: Optional[ParamDrift] = None,
) -> List[PlantStream]:
    """A fleet of plant streams, scenarios assigned round-robin.

    ``names`` defaults to the full library; ``n_plants`` defaults to one plant
    per name.  ``jitter`` overrides every scenario's own jitter; ``drift``
    overrides every scenario's own drift (fleet-wide seasonal/wear drift on
    top of any attack schedule).  Each plant gets a distinct seed (process
    noise and jitter draws decorrelate), and its ``name`` records
    ``{scenario}#{index}`` for verdict attribution.
    """
    names = list(names) if names is not None else list(SCENARIOS)
    if not names:
        raise ValueError("need at least one scenario name")
    n_plants = n_plants if n_plants is not None else len(names)
    base = base_params or PlantParams()
    fleet: List[PlantStream] = []
    for i in range(n_plants):
        sc = get_scenario(names[i % len(names)])
        rel = sc.jitter if jitter is None else jitter
        params = jitter_params(base, rel, np.random.default_rng(seed + 7919 * i))
        fleet.append(PlantStream(params, events=sc.events, seed=seed + i,
                                 name=f"{sc.name}#{i}",
                                 drift=sc.drift if drift is None else drift))
    return fleet


def fleet_readings(
    n_streams: int,
    n_cycles: int,
    *,
    names: Optional[Sequence[str]] = None,
    seed: int = 0,
    jitter: Optional[float] = None,
    drift: Optional[ParamDrift] = None,
) -> np.ndarray:
    """A ``(n_cycles, n_streams, 2)`` raw ``(tb0_meas, wd_meas)`` matrix from
    a scenario fleet — the pre-generated reading block the detection bench
    and the sharded-parity tests drive engines with (simulation cost stays
    out of the serve clock)."""
    fleet = build_fleet(names, n_streams, seed=seed, jitter=jitter,
                        drift=drift)
    out = np.zeros((n_cycles, n_streams, 2), np.float32)
    for c in range(n_cycles):
        for i, s in enumerate(fleet):
            r = s.step()
            out[c, i] = (r.tb0_meas, r.wd_meas)
    return out


def scenario_table() -> str:
    """Human-readable library summary (used by examples/detect_fleet.py).

    ``onsets``/``durations`` list *every* scheduled event — a composed
    multi-attack scenario shows each attack's start cycle and length
    (``rest`` = persists to the end of the run), not just the first one.
    """
    rows = [f"{'name':<24} {'families':<9} {'onsets':<13} {'durations':<13} "
            "events"]
    for s in SCENARIOS.values():
        fams = ",".join(str(f) for f in s.families) or "-"
        onsets = ",".join(str(e.start) for e in s.events) or "-"
        durs = ",".join("rest" if e.duration is None else str(e.duration)
                        for e in s.events) or "-"
        evs = "; ".join(
            f"{ATTACK_NAMES[e.attack_id]}@{e.start}"
            + (f"+{e.duration}" if e.duration is not None else "")
            + (f" x{e.intensity:g}" if e.intensity != 1.0 else "")
            for e in s.events) or "(benign)"
        if s.drift is not None:
            drifted = ",".join(f"{k}{v:+.0%}" for k, v in s.drift.shifts)
            evs += (f" [drift {drifted}@{s.drift.start}"
                    f"+{s.drift.ramp}]")
        rows.append(f"{s.name:<24} {fams:<9} {onsets:<13} {durs:<13} {evs}")
    return "\n".join(rows)
