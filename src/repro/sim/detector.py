"""Head-generic training/eval for the §7 detection workloads.

Two workloads share one MLP-body training loop (Adam, checkpoint-best weight
saving, patience early stopping — the §7 recipe) and differ only in their
:mod:`repro.sim.heads` head:

* **Classifier** (paper-exact §7): 400 inputs (2 feats × 10 Hz × 20 s),
  hidden ReLU layers 64/32/16, 2-class head; sparse categorical
  cross-entropy on labeled windows (the paper uses LR=1e-5 with
  64-epoch-patience early stopping — we keep the architecture/loss/optimizer
  and use a larger LR + smaller patience so the run fits a CPU container).
* **Autoencoder** (unsupervised): 400-64-16-64-400 reconstruction trained on
  *benign* windows only with MSE; the anomaly score is the per-window mean
  squared reconstruction error and the verdict threshold is calibrated to a
  target false-positive rate on held-out normal traces
  (:func:`train_autoencoder`).

Either trained model is the 'established framework' artifact; porting to the
ICSML runtime (§4.3) goes through ``repro.core.porting.port_mlp``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import msf_detector as spec
from repro.core import layers as L
from repro.core import sequential
from repro.core.model import Model, ParamTree
from repro.kernels import ops
from repro.sim.heads import (ClassifierHead, DetectorHead, ForecastHead,
                             MarginHead, ReconstructionHead, ScoreHead)


def build_detector() -> Model:
    """The §7 supervised classifier body: 400-64-32-16-2."""
    hidden = [L.Dense(units=h, activation="relu") for h in spec.HIDDEN]
    return sequential(
        [L.Input()] + hidden + [L.Dense(units=spec.CLASSES, activation="linear")],
        (spec.INPUT_SIZE,),
    )


def build_margin_model() -> Model:
    """The one-class margin body: 400 -> 64 -> 32 -> 16 embedding.

    The §7 hidden trunk with the classifier head cut off — the 16-d linear
    embedding is what :class:`~repro.sim.heads.MarginHead` measures distance
    from its benign center in.  All-Dense, so it serves fused.
    """
    hidden = [L.Dense(units=h, activation="relu") for h in spec.HIDDEN[:-1]]
    return sequential(
        [L.Input()] + hidden
        + [L.Dense(units=spec.MARGIN_EMBED, activation="linear")],
        (spec.INPUT_SIZE,),
    )


def build_forecaster() -> Model:
    """The next-step-prediction body: (W-1) x F = 398 inputs -> one
    F-feature forecast of the next reading.

    One reading narrower than the serving window — the
    :class:`~repro.sim.heads.ForecastHead` asks the engine ring for the
    extra reading and slices the model input off the front of each window.
    """
    hidden = [L.Dense(units=h, activation="relu")
              for h in spec.FORECAST_HIDDEN]
    return sequential(
        [L.Input()] + hidden
        + [L.Dense(units=spec.N_FEATURES, activation="linear")],
        ((spec.WINDOW - 1) * spec.N_FEATURES,),
    )


def build_autoencoder() -> Model:
    """The unsupervised reconstruction body: 400-64-16-64-400.

    All-Dense with pad-safe activations, so it serves through the same fused
    single-dispatch path as the classifier (the 400-wide decoder output rides
    the K-gridded/widest-layer VMEM contract of ``kernels.fused_mlp``).
    """
    hidden = [L.Dense(units=h, activation="relu") for h in spec.AE_HIDDEN]
    return sequential(
        [L.Input()] + hidden
        + [L.Dense(units=spec.INPUT_SIZE, activation="linear")],
        (spec.INPUT_SIZE,),
    )


def batched_forward(model: Model, params: ParamTree, x: jax.Array, *,
                    backend: str = "auto") -> jax.Array:
    """Whole-batch detector outputs: ``(M, in) -> (M, out)``.

    All-Dense stacks (classifier or autoencoder, float or §6.1-quantized)
    run through the fused whole-MLP path — one Pallas dispatch, weights
    VMEM-resident; other models fall back to a vmapped per-sample
    ``model.apply``.
    """
    stack = ops.dense_stack(model, params)
    if ops.model_fusable(model, stack):
        return ops.fused_forward(x, stack, backend=backend)
    return jax.vmap(model.apply, in_axes=(None, 0))(params, x)


def sparse_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return ClassifierHead().loss(logits, None, labels)


@dataclasses.dataclass
class TrainResult:
    params: ParamTree
    history: List[Tuple[int, float, float]]   # (epoch, train_loss, val_metric)
    best_val_acc: float
    test_acc: float


@dataclasses.dataclass
class AETrainResult:
    params: ParamTree
    history: List[Tuple[int, float, float]]   # (epoch, train_mse, -val_mse)
    best_val_mse: float
    head: ReconstructionHead                  # threshold-calibrated
    threshold: float
    calib_fpr: float                          # realized FPR on the calib split
    test_detection_rate: float                # attack windows over threshold
    calib_windows: np.ndarray                 # the held-out normal split —
                                              # re-calibrate on THESE (e.g.
                                              # post-quantization), never on
                                              # training windows


def _fit_head(
    model: Model,
    head: DetectorHead,
    x_train: np.ndarray,
    y_train: Optional[np.ndarray],
    x_val: np.ndarray,
    y_val: Optional[np.ndarray],
    *,
    epochs: int,
    batch_size: int,
    lr: float,
    patience: int,
    seed: int,
) -> Tuple[ParamTree, List[Tuple[int, float, float]], float]:
    """The shared §7 training recipe, parameterized by the head's loss and
    model-selection metric (greater is better): Adam, checkpoint-best weight
    saving, patience early stopping.  Returns (best_params, history,
    best_val_metric)."""
    params = model.init_params(jax.random.PRNGKey(seed))
    batched_apply = jax.vmap(model.apply, in_axes=(None, 0))

    def loss_fn(p, xb, yb):
        # head.prepare is the model-input view of the training windows (the
        # identity for every head except forecast, which slices the target
        # reading off) — the same device-side transform the serving step
        # applies, so train and serve see identical model inputs.
        return head.loss(batched_apply(p, head.prepare(xb)), xb, yb)

    # Adam (paper's optimizer), moments per leaf.
    @jax.jit
    def step(p, m, v, t, xb, yb):
        loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        def upd(pp, mm, vv):
            mh = mm / (1 - b1 ** t)
            vh = vv / (1 - b2 ** t)
            return pp - lr * mh / (jnp.sqrt(vh) + eps)
        return jax.tree.map(upd, p, m, v), m, v, loss

    @jax.jit
    def val_metric(p, xb, yb):
        # Evaluation goes through the fused whole-MLP path (training's
        # gradient path stays on the vmapped apply above).
        return head.metric(batched_forward(model, p, head.prepare(xb)),
                           xb, yb)

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    history: List[Tuple[int, float, float]] = []
    best_val, best_params, since_best = -np.inf, params, 0
    n_train = len(x_train)
    xv = jnp.asarray(x_val)
    yv = None if y_val is None else jnp.asarray(y_val)
    t = 0

    for epoch in range(epochs):
        perm = rng.permutation(n_train)
        losses = []
        for i in range(0, n_train - batch_size + 1, batch_size):
            idx = perm[i:i + batch_size]
            t += 1
            yb = None if y_train is None else jnp.asarray(y_train[idx])
            params, m, v, loss = step(params, m, v, t,
                                      jnp.asarray(x_train[idx]), yb)
            losses.append(float(loss))
        val = float(val_metric(params, xv, yv))
        history.append((epoch, float(np.mean(losses)), val))
        if val > best_val:                # checkpoint-best (§7)
            best_val, best_params, since_best = val, params, 0
        else:
            since_best += 1
            if since_best >= patience:    # early stopping (§7)
                break

    return best_params, history, best_val


def train_detector(
    x: np.ndarray,
    y: np.ndarray,
    *,
    epochs: int = 60,
    batch_size: int = 256,
    lr: float = 3e-4,
    patience: int = 8,
    seed: int = 0,
    splits: Tuple[float, float, float] = (0.7225, 0.1275, 0.15),  # §7
) -> Tuple[Model, TrainResult]:
    """The supervised §7 classifier: labeled windows, CE loss, argmax."""
    model = build_detector()
    head = ClassifierHead()

    n = len(x)
    n_train = int(splits[0] * n)
    n_val = int(splits[1] * n)
    x_train, y_train = x[:n_train], y[:n_train]
    x_val, y_val = x[n_train:n_train + n_val], y[n_train:n_train + n_val]
    x_test, y_test = x[n_train + n_val:], y[n_train + n_val:]

    params, history, best_val = _fit_head(
        model, head, x_train, y_train, x_val, y_val, epochs=epochs,
        batch_size=batch_size, lr=lr, patience=patience, seed=seed)

    test_acc = float(head.metric(
        batched_forward(model, params, jnp.asarray(x_test)), None,
        jnp.asarray(y_test)))
    return model, TrainResult(params=params, history=history,
                              best_val_acc=best_val, test_acc=test_acc)


def score_windows(
    model: Model,
    params: ParamTree,
    head: ScoreHead,
    windows,
    *,
    backend: str = "auto",
) -> np.ndarray:
    """Per-window anomaly scores of ``head`` over batched ``windows`` —
    the head's prepare -> fused batched forward -> batch_scores sequence,
    shared by calibration, detection-rate reporting and tests."""
    w = jnp.asarray(windows)
    return np.asarray(head.batch_scores(
        batched_forward(model, params, head.prepare(w), backend=backend), w))


def recalibrate_threshold(
    model: Model,
    params: ParamTree,
    windows,
    *,
    head: Optional[ScoreHead] = None,
    target_fpr: float = spec.AE_TARGET_FPR,
    backend: str = "auto",
) -> Tuple[ScoreHead, np.ndarray]:
    """Calibrate a :class:`ScoreHead` threshold against THIS model/params'
    anomaly scores on held-out **normal** windows.

    The single source of the score-then-quantile sequence: initial training
    calibration and every re-calibration (post-quantization, post-porting)
    go through here, so the held-out-windows invariant — never calibrate on
    training windows, they score optimistically and bias the quantile low —
    lives in one place for every score head (reconstruction, margin,
    forecast).  ``head`` defaults to an uncalibrated
    :class:`ReconstructionHead`.  Returns ``(calibrated_head, scores)``.
    """
    head = ReconstructionHead() if head is None else head
    scores = score_windows(model, params, head, windows, backend=backend)
    return head.calibrate(scores, target_fpr), scores


@dataclasses.dataclass
class ScoreTrainResult:
    """Result of the generic unsupervised (score-head) trainer."""

    params: ParamTree
    history: List[Tuple[int, float, float]]   # (epoch, train_score, -val)
    best_val: float                           # best validation mean score
    head: ScoreHead                           # threshold-calibrated
    threshold: float
    calib_fpr: float                          # realized FPR on the calib split
    test_detection_rate: float                # attack windows over threshold
    calib_windows: np.ndarray                 # the held-out normal split —
                                              # re-calibrate on THESE (e.g.
                                              # post-quantization), never on
                                              # training windows


def _split_benign(x, y, batch_size, what):
    if y is not None:
        normal = x[np.asarray(y) == 0]
        attacks = x[np.asarray(y) != 0]
    else:
        normal, attacks = x, None
    if len(normal) < 3 * batch_size:
        raise ValueError(
            f"need >= {3 * batch_size} benign windows to train/val/calibrate "
            f"{what}, got {len(normal)}")
    return normal, attacks


def _train_score_head(
    model: Model,
    head: ScoreHead,
    x: np.ndarray,
    y: Optional[np.ndarray],
    *,
    epochs: int,
    batch_size: int,
    lr: float,
    patience: int,
    seed: int,
    splits: Tuple[float, float, float],
    target_fpr: float,
) -> ScoreTrainResult:
    """The shared unsupervised recipe: fit ``head``'s score objective on
    **benign windows only** (labels, when given, solely drop attack windows
    — the label-free half of the ICS-defense space), calibrate the verdict
    threshold to ``target_fpr`` on a held-out normal split the optimizer
    never saw, and report the detection rate over the dropped attacks."""
    normal, attacks = _split_benign(x, y, batch_size, f"the {head.name} head")
    n = len(normal)
    n_train = int(splits[0] * n)
    n_val = int(splits[1] * n)
    x_train = normal[:n_train]
    x_val = normal[n_train:n_train + n_val]
    x_calib = normal[n_train + n_val:]        # held-out normal traces

    params, history, best_val = _fit_head(
        model, head, x_train, None, x_val, None, epochs=epochs,
        batch_size=batch_size, lr=lr, patience=patience, seed=seed)

    # Threshold calibration: the conservative (1 - target_fpr) quantile of
    # anomaly score on held-out normal windows the optimizer never touched.
    head, calib_scores = recalibrate_threshold(model, params, x_calib,
                                               head=head,
                                               target_fpr=target_fpr)
    calib_fpr = float(np.mean(calib_scores > head.threshold))

    detection = 0.0
    if attacks is not None and len(attacks):
        attack_scores = score_windows(model, params, head, attacks)
        detection = float(np.mean(attack_scores > head.threshold))

    return ScoreTrainResult(
        params=params, history=history, best_val=-best_val, head=head,
        threshold=head.threshold, calib_fpr=calib_fpr,
        test_detection_rate=detection, calib_windows=x_calib)


def train_autoencoder(
    x: np.ndarray,
    y: Optional[np.ndarray] = None,
    *,
    epochs: int = 60,
    batch_size: int = 256,
    lr: float = 1e-3,
    patience: int = 8,
    seed: int = 0,
    splits: Tuple[float, float, float] = (0.7225, 0.1275, 0.15),
    target_fpr: float = spec.AE_TARGET_FPR,
) -> Tuple[Model, AETrainResult]:
    """The unsupervised reconstruction detector: the 400-64-16-64-400
    autoencoder under the shared score-head recipe (benign-only MSE,
    held-out FPR calibration — :func:`_train_score_head`).

    Returns the model plus an :class:`AETrainResult` whose ``head`` is the
    calibrated :class:`ReconstructionHead` to serve with
    (``StreamEngine(model, params, head=result.head, ...)``).
    """
    model = build_autoencoder()
    res = _train_score_head(
        model, ReconstructionHead(), x, y, epochs=epochs,
        batch_size=batch_size, lr=lr, patience=patience, seed=seed,
        splits=splits, target_fpr=target_fpr)
    return model, AETrainResult(
        params=res.params, history=res.history, best_val_mse=res.best_val,
        head=res.head, threshold=res.threshold, calib_fpr=res.calib_fpr,
        test_detection_rate=res.test_detection_rate,
        calib_windows=res.calib_windows)


def train_one_class(
    x: np.ndarray,
    y: Optional[np.ndarray] = None,
    *,
    epochs: int = 60,
    batch_size: int = 256,
    lr: float = 1e-3,
    patience: int = 8,
    seed: int = 0,
    splits: Tuple[float, float, float] = (0.7225, 0.1275, 0.15),
    target_fpr: float = spec.AE_TARGET_FPR,
) -> Tuple[Model, ScoreTrainResult]:
    """The one-class margin detector (Deep-SVDD-style): embed windows with
    the §7 trunk (:func:`build_margin_model`), fix the center at the mean
    *initial* embedding of the benign training windows (the standard SVDD
    center init — a trainable center collapses), then minimize the mean
    squared distance of benign embeddings from it.  The calibrated
    threshold is the margin radius.
    """
    model = build_margin_model()
    normal, _ = _split_benign(x, y, batch_size, "the margin head")
    # Center from the untrained embedding of benign windows; freezing it
    # before optimization is what makes "pull everything to the center" a
    # non-degenerate objective.
    n_train = int(splits[0] * len(normal))
    init_params = model.init_params(jax.random.PRNGKey(seed))
    emb = batched_forward(model, init_params,
                          jnp.asarray(normal[:n_train]))
    center = tuple(float(c) for c in np.asarray(jnp.mean(emb, axis=0)))
    res = _train_score_head(
        model, MarginHead(center=center), x, y, epochs=epochs,
        batch_size=batch_size, lr=lr, patience=patience, seed=seed,
        splits=splits, target_fpr=target_fpr)
    return model, res


def train_forecaster(
    x: np.ndarray,
    y: Optional[np.ndarray] = None,
    *,
    epochs: int = 60,
    batch_size: int = 256,
    lr: float = 1e-3,
    patience: int = 8,
    seed: int = 0,
    splits: Tuple[float, float, float] = (0.7225, 0.1275, 0.15),
    target_fpr: float = spec.AE_TARGET_FPR,
) -> Tuple[Model, ScoreTrainResult]:
    """The next-step-prediction detector: :func:`build_forecaster` maps each
    window's first W-1 readings to a forecast of the W-th (the
    :class:`~repro.sim.heads.ForecastHead` owns the slicing), trained on
    benign windows so attacks surface as unforecastable transitions.

    ``x`` rows are FULL ``spec.INPUT_SIZE`` windows — the same dataset the
    other detectors train on; the head carves input and target out of each.
    """
    model = build_forecaster()
    res = _train_score_head(
        model, ForecastHead(n_features=spec.N_FEATURES), x, y, epochs=epochs,
        batch_size=batch_size, lr=lr, patience=patience, seed=seed,
        splits=splits, target_fpr=target_fpr)
    return model, res
