"""Train the §7 anomaly-detection classifier and port it to the ICSML core.

Model (paper-exact): 400 inputs (2 feats × 10 Hz × 20 s), hidden ReLU layers
64/32/16, 2-class softmax head; sparse categorical cross-entropy, Adam
(paper uses LR=1e-5 with 64-epoch-patience early stopping — we keep the
architecture/loss/optimizer and use a larger LR + smaller patience so the run
fits a CPU container), checkpoint-best weight saving.

The trained model is the 'established framework' artifact; porting to the
ICSML runtime (§4.3) goes through ``repro.core.porting.port_mlp``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import msf_detector as spec
from repro.core import layers as L
from repro.core import sequential
from repro.core.model import Model, ParamTree
from repro.kernels import ops


def build_detector() -> Model:
    hidden = [L.Dense(units=h, activation="relu") for h in spec.HIDDEN]
    return sequential(
        [L.Input()] + hidden + [L.Dense(units=spec.CLASSES, activation="linear")],
        (spec.INPUT_SIZE,),
    )


def batched_forward(model: Model, params: ParamTree, x: jax.Array, *,
                    backend: str = "auto") -> jax.Array:
    """Whole-batch detector logits: ``(M, in) -> (M, classes)``.

    All-Dense stacks (the detector, float or §6.1-quantized) run through the
    fused whole-MLP path — one Pallas dispatch, weights VMEM-resident; other
    models fall back to a vmapped per-sample ``model.apply``.
    """
    stack = ops.dense_stack(model, params)
    if ops.model_fusable(model, stack):
        return ops.fused_forward(x, stack, backend=backend)
    return jax.vmap(model.apply, in_axes=(None, 0))(params, x)


def sparse_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


@dataclasses.dataclass
class TrainResult:
    params: ParamTree
    history: List[Tuple[int, float, float]]   # (epoch, train_loss, val_acc)
    best_val_acc: float
    test_acc: float


def train_detector(
    x: np.ndarray,
    y: np.ndarray,
    *,
    epochs: int = 60,
    batch_size: int = 256,
    lr: float = 3e-4,
    patience: int = 8,
    seed: int = 0,
    splits: Tuple[float, float, float] = (0.7225, 0.1275, 0.15),  # §7
) -> Tuple[Model, TrainResult]:
    model = build_detector()
    params = model.init_params(jax.random.PRNGKey(seed))

    n = len(x)
    n_train = int(splits[0] * n)
    n_val = int(splits[1] * n)
    x_train, y_train = x[:n_train], y[:n_train]
    x_val, y_val = x[n_train:n_train + n_val], y[n_train:n_train + n_val]
    x_test, y_test = x[n_train + n_val:], y[n_train + n_val:]

    batched_apply = jax.vmap(model.apply, in_axes=(None, 0))

    def loss_fn(p, xb, yb):
        return sparse_ce(batched_apply(p, xb), yb)

    # Adam (paper's optimizer), moments per leaf.
    @jax.jit
    def step(p, m, v, t, xb, yb):
        loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        def upd(pp, mm, vv):
            mh = mm / (1 - b1 ** t)
            vh = vv / (1 - b2 ** t)
            return pp - lr * mh / (jnp.sqrt(vh) + eps)
        return jax.tree.map(upd, p, m, v), m, v, loss

    @jax.jit
    def accuracy(p, xb, yb):
        # Evaluation goes through the fused whole-MLP path (training's
        # gradient path stays on the vmapped apply above).
        pred = jnp.argmax(batched_forward(model, p, xb), axis=-1)
        return jnp.mean(pred == yb)

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    history: List[Tuple[int, float, float]] = []
    best_val, best_params, since_best = -1.0, params, 0
    t = 0

    for epoch in range(epochs):
        perm = rng.permutation(n_train)
        losses = []
        for i in range(0, n_train - batch_size + 1, batch_size):
            idx = perm[i:i + batch_size]
            t += 1
            params, m, v, loss = step(params, m, v, t,
                                      jnp.asarray(x_train[idx]),
                                      jnp.asarray(y_train[idx]))
            losses.append(float(loss))
        val_acc = float(accuracy(params, jnp.asarray(x_val), jnp.asarray(y_val)))
        history.append((epoch, float(np.mean(losses)), val_acc))
        if val_acc > best_val:            # checkpoint-best (§7)
            best_val, best_params, since_best = val_acc, params, 0
        else:
            since_best += 1
            if since_best >= patience:    # early stopping (§7)
                break

    test_acc = float(accuracy(best_params, jnp.asarray(x_test), jnp.asarray(y_test)))
    return model, TrainResult(params=best_params, history=history,
                              best_val_acc=best_val, test_acc=test_acc)
