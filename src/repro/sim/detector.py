"""Head-generic training/eval for the §7 detection workloads.

Two workloads share one MLP-body training loop (Adam, checkpoint-best weight
saving, patience early stopping — the §7 recipe) and differ only in their
:mod:`repro.sim.heads` head:

* **Classifier** (paper-exact §7): 400 inputs (2 feats × 10 Hz × 20 s),
  hidden ReLU layers 64/32/16, 2-class head; sparse categorical
  cross-entropy on labeled windows (the paper uses LR=1e-5 with
  64-epoch-patience early stopping — we keep the architecture/loss/optimizer
  and use a larger LR + smaller patience so the run fits a CPU container).
* **Autoencoder** (unsupervised): 400-64-16-64-400 reconstruction trained on
  *benign* windows only with MSE; the anomaly score is the per-window mean
  squared reconstruction error and the verdict threshold is calibrated to a
  target false-positive rate on held-out normal traces
  (:func:`train_autoencoder`).

Either trained model is the 'established framework' artifact; porting to the
ICSML runtime (§4.3) goes through ``repro.core.porting.port_mlp``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import msf_detector as spec
from repro.core import layers as L
from repro.core import sequential
from repro.core.model import Model, ParamTree
from repro.kernels import ops
from repro.sim.heads import ClassifierHead, DetectorHead, ReconstructionHead


def build_detector() -> Model:
    """The §7 supervised classifier body: 400-64-32-16-2."""
    hidden = [L.Dense(units=h, activation="relu") for h in spec.HIDDEN]
    return sequential(
        [L.Input()] + hidden + [L.Dense(units=spec.CLASSES, activation="linear")],
        (spec.INPUT_SIZE,),
    )


def build_autoencoder() -> Model:
    """The unsupervised reconstruction body: 400-64-16-64-400.

    All-Dense with pad-safe activations, so it serves through the same fused
    single-dispatch path as the classifier (the 400-wide decoder output rides
    the K-gridded/widest-layer VMEM contract of ``kernels.fused_mlp``).
    """
    hidden = [L.Dense(units=h, activation="relu") for h in spec.AE_HIDDEN]
    return sequential(
        [L.Input()] + hidden
        + [L.Dense(units=spec.INPUT_SIZE, activation="linear")],
        (spec.INPUT_SIZE,),
    )


def batched_forward(model: Model, params: ParamTree, x: jax.Array, *,
                    backend: str = "auto") -> jax.Array:
    """Whole-batch detector outputs: ``(M, in) -> (M, out)``.

    All-Dense stacks (classifier or autoencoder, float or §6.1-quantized)
    run through the fused whole-MLP path — one Pallas dispatch, weights
    VMEM-resident; other models fall back to a vmapped per-sample
    ``model.apply``.
    """
    stack = ops.dense_stack(model, params)
    if ops.model_fusable(model, stack):
        return ops.fused_forward(x, stack, backend=backend)
    return jax.vmap(model.apply, in_axes=(None, 0))(params, x)


def sparse_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return ClassifierHead().loss(logits, None, labels)


@dataclasses.dataclass
class TrainResult:
    params: ParamTree
    history: List[Tuple[int, float, float]]   # (epoch, train_loss, val_metric)
    best_val_acc: float
    test_acc: float


@dataclasses.dataclass
class AETrainResult:
    params: ParamTree
    history: List[Tuple[int, float, float]]   # (epoch, train_mse, -val_mse)
    best_val_mse: float
    head: ReconstructionHead                  # threshold-calibrated
    threshold: float
    calib_fpr: float                          # realized FPR on the calib split
    test_detection_rate: float                # attack windows over threshold
    calib_windows: np.ndarray                 # the held-out normal split —
                                              # re-calibrate on THESE (e.g.
                                              # post-quantization), never on
                                              # training windows


def _fit_head(
    model: Model,
    head: DetectorHead,
    x_train: np.ndarray,
    y_train: Optional[np.ndarray],
    x_val: np.ndarray,
    y_val: Optional[np.ndarray],
    *,
    epochs: int,
    batch_size: int,
    lr: float,
    patience: int,
    seed: int,
) -> Tuple[ParamTree, List[Tuple[int, float, float]], float]:
    """The shared §7 training recipe, parameterized by the head's loss and
    model-selection metric (greater is better): Adam, checkpoint-best weight
    saving, patience early stopping.  Returns (best_params, history,
    best_val_metric)."""
    params = model.init_params(jax.random.PRNGKey(seed))
    batched_apply = jax.vmap(model.apply, in_axes=(None, 0))

    def loss_fn(p, xb, yb):
        return head.loss(batched_apply(p, xb), xb, yb)

    # Adam (paper's optimizer), moments per leaf.
    @jax.jit
    def step(p, m, v, t, xb, yb):
        loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        def upd(pp, mm, vv):
            mh = mm / (1 - b1 ** t)
            vh = vv / (1 - b2 ** t)
            return pp - lr * mh / (jnp.sqrt(vh) + eps)
        return jax.tree.map(upd, p, m, v), m, v, loss

    @jax.jit
    def val_metric(p, xb, yb):
        # Evaluation goes through the fused whole-MLP path (training's
        # gradient path stays on the vmapped apply above).
        return head.metric(batched_forward(model, p, xb), xb, yb)

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    history: List[Tuple[int, float, float]] = []
    best_val, best_params, since_best = -np.inf, params, 0
    n_train = len(x_train)
    xv = jnp.asarray(x_val)
    yv = None if y_val is None else jnp.asarray(y_val)
    t = 0

    for epoch in range(epochs):
        perm = rng.permutation(n_train)
        losses = []
        for i in range(0, n_train - batch_size + 1, batch_size):
            idx = perm[i:i + batch_size]
            t += 1
            yb = None if y_train is None else jnp.asarray(y_train[idx])
            params, m, v, loss = step(params, m, v, t,
                                      jnp.asarray(x_train[idx]), yb)
            losses.append(float(loss))
        val = float(val_metric(params, xv, yv))
        history.append((epoch, float(np.mean(losses)), val))
        if val > best_val:                # checkpoint-best (§7)
            best_val, best_params, since_best = val, params, 0
        else:
            since_best += 1
            if since_best >= patience:    # early stopping (§7)
                break

    return best_params, history, best_val


def train_detector(
    x: np.ndarray,
    y: np.ndarray,
    *,
    epochs: int = 60,
    batch_size: int = 256,
    lr: float = 3e-4,
    patience: int = 8,
    seed: int = 0,
    splits: Tuple[float, float, float] = (0.7225, 0.1275, 0.15),  # §7
) -> Tuple[Model, TrainResult]:
    """The supervised §7 classifier: labeled windows, CE loss, argmax."""
    model = build_detector()
    head = ClassifierHead()

    n = len(x)
    n_train = int(splits[0] * n)
    n_val = int(splits[1] * n)
    x_train, y_train = x[:n_train], y[:n_train]
    x_val, y_val = x[n_train:n_train + n_val], y[n_train:n_train + n_val]
    x_test, y_test = x[n_train + n_val:], y[n_train + n_val:]

    params, history, best_val = _fit_head(
        model, head, x_train, y_train, x_val, y_val, epochs=epochs,
        batch_size=batch_size, lr=lr, patience=patience, seed=seed)

    test_acc = float(head.metric(
        batched_forward(model, params, jnp.asarray(x_test)), None,
        jnp.asarray(y_test)))
    return model, TrainResult(params=params, history=history,
                              best_val_acc=best_val, test_acc=test_acc)


def recalibrate_threshold(
    model: Model,
    params: ParamTree,
    windows,
    *,
    target_fpr: float = spec.AE_TARGET_FPR,
    backend: str = "auto",
) -> Tuple[ReconstructionHead, np.ndarray]:
    """Calibrate a :class:`ReconstructionHead` threshold against THIS
    model/params' reconstruction scores on held-out **normal** windows.

    The single source of the score-then-quantile sequence: initial training
    calibration and every re-calibration (post-quantization, post-porting)
    go through here, so the held-out-windows invariant — never calibrate on
    training windows, they reconstruct optimistically and bias the quantile
    low — lives in one place.  Returns ``(calibrated_head, scores)``.
    """
    w = jnp.asarray(windows)
    scores = np.asarray(ReconstructionHead().scores(
        batched_forward(model, params, w, backend=backend), w))
    return ReconstructionHead().calibrate(scores, target_fpr), scores


def train_autoencoder(
    x: np.ndarray,
    y: Optional[np.ndarray] = None,
    *,
    epochs: int = 60,
    batch_size: int = 256,
    lr: float = 1e-3,
    patience: int = 8,
    seed: int = 0,
    splits: Tuple[float, float, float] = (0.7225, 0.1275, 0.15),
    target_fpr: float = spec.AE_TARGET_FPR,
) -> Tuple[Model, AETrainResult]:
    """The unsupervised detector: train the 400-64-16-64-400 autoencoder on
    **benign windows only** (labels, when given, are used solely to drop
    attack windows from training — the label-free half of the ICS-defense
    space), then calibrate the verdict threshold to ``target_fpr`` false
    positives on a held-out normal split the optimizer never saw.

    Returns the model plus an :class:`AETrainResult` whose ``head`` is the
    calibrated :class:`ReconstructionHead` to serve with
    (``StreamEngine(model, params, head=result.head, ...)``).
    """
    head = ReconstructionHead()
    if y is not None:
        normal = x[np.asarray(y) == 0]
        attacks = x[np.asarray(y) != 0]
    else:
        normal, attacks = x, None
    if len(normal) < 3 * batch_size:
        raise ValueError(
            f"need >= {3 * batch_size} benign windows to train/val/calibrate "
            f"the autoencoder, got {len(normal)}")

    model = build_autoencoder()
    n = len(normal)
    n_train = int(splits[0] * n)
    n_val = int(splits[1] * n)
    x_train = normal[:n_train]
    x_val = normal[n_train:n_train + n_val]
    x_calib = normal[n_train + n_val:]        # held-out normal traces

    params, history, best_val = _fit_head(
        model, head, x_train, None, x_val, None, epochs=epochs,
        batch_size=batch_size, lr=lr, patience=patience, seed=seed)

    # Threshold calibration: the (1 - target_fpr) quantile of reconstruction
    # error on held-out normal windows the optimizer never touched.
    head, calib_scores = recalibrate_threshold(model, params, x_calib,
                                               target_fpr=target_fpr)
    calib_fpr = float(np.mean(calib_scores > head.threshold))

    detection = 0.0
    if attacks is not None and len(attacks):
        attack_scores = np.asarray(ReconstructionHead().scores(
            batched_forward(model, params, jnp.asarray(attacks)),
            jnp.asarray(attacks)))
        detection = float(np.mean(attack_scores > head.threshold))

    return model, AETrainResult(
        params=params, history=history, best_val_mse=-best_val, head=head,
        threshold=head.threshold, calib_fpr=calib_fpr,
        test_detection_rate=detection, calib_windows=x_calib)
