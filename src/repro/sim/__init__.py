from repro.sim.detector import TrainResult, build_detector, train_detector
from repro.sim.msf import (MSFPlant, CascadePID, SimTrace, adc, build_dataset,
                           make_attacks, simulate)

__all__ = ["TrainResult", "build_detector", "train_detector", "MSFPlant",
           "CascadePID", "SimTrace", "adc", "build_dataset", "make_attacks",
           "simulate"]
