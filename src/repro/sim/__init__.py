from repro.sim.detector import (TrainResult, batched_forward, build_detector,
                                train_detector)
from repro.sim.msf import (ATTACK_NAMES, AttackEvent, CascadePID, CycleReading,
                           MSFPlant, PlantParams, PlantStream, SimTrace, adc,
                           build_dataset, make_attack, make_attacks, simulate)
from repro.sim.scenarios import (SCENARIOS, Scenario, build_fleet,
                                 fleet_readings, get_scenario, jitter_params,
                                 list_scenarios, register_scenario,
                                 scenario_table)

__all__ = ["TrainResult", "batched_forward", "build_detector",
           "train_detector", "ATTACK_NAMES",
           "AttackEvent", "CascadePID", "CycleReading", "MSFPlant",
           "PlantParams", "PlantStream", "SimTrace", "adc", "build_dataset",
           "make_attack", "make_attacks", "simulate", "SCENARIOS", "Scenario",
           "build_fleet", "fleet_readings", "get_scenario", "jitter_params",
           "list_scenarios", "register_scenario", "scenario_table"]
