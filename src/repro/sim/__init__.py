from repro.sim.detector import (AETrainResult, TrainResult, batched_forward,
                                build_autoencoder, build_detector,
                                recalibrate_threshold, train_autoencoder,
                                train_detector)
from repro.sim.heads import (ClassifierHead, DetectorHead, ReconstructionHead,
                             softmax_np)
from repro.sim.msf import (ATTACK_NAMES, AttackEvent, CascadePID, CycleReading,
                           MSFPlant, PlantParams, PlantStream, SimTrace, adc,
                           build_dataset, make_attack, make_attacks, simulate)
from repro.sim.scenarios import (SCENARIOS, Scenario, build_fleet,
                                 fleet_readings, get_scenario, jitter_params,
                                 list_scenarios, register_scenario,
                                 scenario_table)

__all__ = ["AETrainResult", "TrainResult", "batched_forward",
           "build_autoencoder", "build_detector", "recalibrate_threshold",
           "train_autoencoder",
           "train_detector", "ClassifierHead", "DetectorHead",
           "ReconstructionHead", "softmax_np", "ATTACK_NAMES",
           "AttackEvent", "CascadePID", "CycleReading", "MSFPlant",
           "PlantParams", "PlantStream", "SimTrace", "adc", "build_dataset",
           "make_attack", "make_attacks", "simulate", "SCENARIOS", "Scenario",
           "build_fleet", "fleet_readings", "get_scenario", "jitter_params",
           "list_scenarios", "register_scenario", "scenario_table"]
