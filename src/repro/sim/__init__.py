from repro.sim.detector import (AETrainResult, ScoreTrainResult, TrainResult,
                                batched_forward, build_autoencoder,
                                build_detector, build_forecaster,
                                build_margin_model, recalibrate_threshold,
                                score_windows, train_autoencoder,
                                train_detector, train_forecaster,
                                train_one_class)
from repro.sim.heads import (ClassifierHead, DetectorHead, ForecastHead,
                             MarginHead, ReconstructionHead, ScoreHead,
                             conservative_quantile, softmax_np)
from repro.sim.msf import (ATTACK_NAMES, DRIFTABLE, AttackEvent, CascadePID,
                           CycleReading, MSFPlant, ParamDrift, PlantParams,
                           PlantStream, SimTrace, adc, build_dataset,
                           make_attack, make_attacks, simulate)
from repro.sim.scenarios import (SCENARIOS, Scenario, build_fleet,
                                 fleet_readings, get_scenario, jitter_params,
                                 list_scenarios, register_scenario, registered,
                                 scenario_table, unregister_scenario)

__all__ = ["AETrainResult", "ScoreTrainResult", "TrainResult",
           "batched_forward", "build_autoencoder", "build_detector",
           "build_forecaster", "build_margin_model", "recalibrate_threshold",
           "score_windows", "train_autoencoder", "train_detector",
           "train_forecaster", "train_one_class", "ClassifierHead",
           "DetectorHead", "ForecastHead", "MarginHead", "ReconstructionHead",
           "ScoreHead", "conservative_quantile", "softmax_np", "ATTACK_NAMES",
           "DRIFTABLE", "AttackEvent", "CascadePID", "CycleReading",
           "MSFPlant", "ParamDrift", "PlantParams", "PlantStream", "SimTrace",
           "adc", "build_dataset",
           "make_attack", "make_attacks", "simulate", "SCENARIOS", "Scenario",
           "build_fleet", "fleet_readings", "get_scenario", "jitter_params",
           "list_scenarios", "register_scenario", "registered",
           "scenario_table", "unregister_scenario"]
