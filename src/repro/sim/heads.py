"""Detector heads: what a detection workload computes *after* the MLP body.

The §7 case study hardwired one head — a 2-class softmax classifier (CE loss,
argmax verdict) — into three layers at once: training (`sim.detector`),
serving (`serving.streams`' inlined softmax/argmax epilogue) and the fused
kernel contract.  The dominant ICS-defense pattern is *unsupervised* anomaly
detection (train on benign traffic only, flag by reconstruction error), which
shares the whole MLP body / fused-kernel / fleet-serving machinery and differs
only in the head.  This module makes the head a first-class object:

* :class:`ClassifierHead` — supervised: sparse-CE loss over labeled windows,
  verdict = argmax class with its softmax probability.
* :class:`ReconstructionHead` — unsupervised: MSE loss on benign windows
  only, anomaly score = per-window mean squared reconstruction error,
  verdict = score > threshold, the threshold calibrated to a target
  false-positive rate on held-out normal traces.

A head contributes three things:

1. ``loss(outputs, x, y)`` — the training objective (``sim.detector``'s
   head-generic Adam loop calls it on batched model outputs).
2. ``epilogue(win, out)`` — the **device-side** verdict reduction, traced
   into the engine's jitted step (sharded and unsharded): for the classifier
   it is the identity on the logits; for reconstruction it reduces the
   (S, 400) reconstructions to an (S, 1) score **on device**, so the host
   never materializes fleet x 400 reconstructions.
3. ``host_verdicts(out)`` — the host-side epilogue turning the step output
   into per-stream ``(pred, prob, score, threshold)`` verdict fields.

Heads are stream-local (row-wise), so the epilogue rides through
``shard_map`` untouched — the fleet mesh sees zero new collectives.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def softmax_np(logits: np.ndarray) -> np.ndarray:
    """Batched-stable host softmax: subtracts the per-row max along the last
    axis before exponentiating, so rows of extreme logits (|z| ~ 1e4, the
    saturated-detector regime) never overflow ``exp``."""
    z = logits - logits.max(axis=-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


class DetectorHead:
    """Base: the loss / device epilogue / host verdict of one workload."""

    name: str = "?"

    def loss(self, outputs: jax.Array, x: jax.Array,
             y: Optional[jax.Array]) -> jax.Array:
        """Training objective over batched model outputs."""
        raise NotImplementedError

    def metric(self, outputs: jax.Array, x: jax.Array,
               y: Optional[jax.Array]) -> jax.Array:
        """Scalar model-selection metric — greater is better (checkpoint-best
        and early stopping in the head-generic trainer key on it)."""
        raise NotImplementedError

    def validate(self, input_size: int, n_outputs: int) -> None:
        """Raise early (engine construction) if the model can't carry this
        head; the default accepts any output width."""

    def epilogue(self, win: jax.Array, out: jax.Array) -> jax.Array:
        """Device-side reduction from raw model outputs to the per-stream
        verdict payload; traced into the engine's jitted detector step."""
        raise NotImplementedError

    def host_verdicts(self, out: np.ndarray) -> Tuple[
            np.ndarray, Optional[np.ndarray], Optional[np.ndarray],
            Optional[float]]:
        """Step output -> (pred, prob|None, score|None, threshold|None),
        each an array over streams (threshold is one float for the fleet)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ClassifierHead(DetectorHead):
    """Supervised classifier: CE loss, argmax verdict (§7's head)."""

    name: str = "classifier"

    def loss(self, outputs, x, y):
        logz = jax.scipy.special.logsumexp(outputs, axis=-1)
        gold = jnp.take_along_axis(outputs, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    def metric(self, outputs, x, y):
        return jnp.mean(jnp.argmax(outputs, axis=-1) == y)

    def epilogue(self, win, out):
        return out                      # the logits ARE the verdict payload

    def host_verdicts(self, out):
        pred = out.argmax(axis=-1)
        prob = softmax_np(out)[np.arange(len(out)), pred]
        return pred.astype(np.int64), prob, None, None


@dataclasses.dataclass(frozen=True)
class ReconstructionHead(DetectorHead):
    """Unsupervised autoencoder: MSE loss on benign windows, anomaly score =
    per-window mean squared reconstruction error, verdict = score exceeding
    a threshold calibrated to ``target_fpr`` on held-out normal traces.

    ``threshold`` is None until calibrated (:meth:`calibrate` /
    ``sim.detector.train_autoencoder``); serving requires it.
    """

    threshold: Optional[float] = None
    name: str = "reconstruction"

    def loss(self, outputs, x, y):
        return jnp.mean(self.scores(outputs, x))

    def metric(self, outputs, x, y):
        # Lower reconstruction error is better; the trainer maximizes.
        return -self.loss(outputs, x, y)

    def validate(self, input_size: int, n_outputs: int) -> None:
        if n_outputs != input_size:
            raise ValueError(
                f"ReconstructionHead needs an autoencoder whose output width "
                f"({n_outputs}) equals its input width ({input_size})")
        if self.threshold is None:
            raise ValueError(
                "ReconstructionHead has no threshold; calibrate it on "
                "held-out normal traces first (head.calibrate / "
                "sim.detector.train_autoencoder)")

    def epilogue(self, win, out):
        # On-device score reduction: (S, 400) reconstructions -> (S, 1)
        # errors before anything leaves the device, so a sharded fleet ships
        # one float per stream to the host rather than the full decode.
        return self.scores(out, win)[:, None]

    def scores(self, recon: jax.Array, x: jax.Array) -> jax.Array:
        """Per-window anomaly scores from batched reconstructions."""
        return jnp.mean(jnp.square(recon - x), axis=-1)

    def calibrate(self, normal_scores: np.ndarray,
                  target_fpr: float) -> "ReconstructionHead":
        """A new head whose threshold yields ``target_fpr`` false positives
        on the given held-out *normal* window scores."""
        if not 0.0 < target_fpr < 1.0:
            raise ValueError(f"target_fpr must be in (0, 1), got {target_fpr}")
        scores = np.asarray(normal_scores, np.float64)
        if scores.size == 0:
            raise ValueError("cannot calibrate on zero normal scores")
        thr = float(np.quantile(scores, 1.0 - target_fpr))
        return dataclasses.replace(self, threshold=thr)

    def host_verdicts(self, out):
        if self.threshold is None:
            raise ValueError(
                "ReconstructionHead has no threshold; calibrate it on "
                "held-out normal traces first (head.calibrate / "
                "sim.detector.train_autoencoder)")
        score = out[:, 0] if out.ndim == 2 else out
        pred = (score > self.threshold).astype(np.int64)
        return pred, None, score, self.threshold
