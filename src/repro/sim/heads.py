"""Detector heads: what a detection workload computes *after* the MLP body.

The §7 case study hardwired one head — a 2-class softmax classifier (CE loss,
argmax verdict) — into three layers at once: training (`sim.detector`),
serving (`serving.streams`' inlined softmax/argmax epilogue) and the fused
kernel contract.  The dominant ICS-defense pattern is *unsupervised* anomaly
detection (train on benign traffic only, flag by an anomaly score), which
shares the whole MLP body / fused-kernel / fleet-serving machinery and differs
only in the head.  This module makes the head a first-class object:

* :class:`ClassifierHead` — supervised: sparse-CE loss over labeled windows,
  verdict = argmax class with its softmax probability.
* :class:`ReconstructionHead` — unsupervised: MSE loss on benign windows
  only, anomaly score = per-window mean squared reconstruction error.
* :class:`MarginHead` — unsupervised one-class margin (Deep-SVDD-style):
  the body embeds a window near a fixed benign ``center``; the anomaly
  score is the mean squared distance of the embedding from the center, and
  the calibrated threshold is the margin radius.
* :class:`ForecastHead` — unsupervised next-step prediction: the body maps
  the window's first ``W - 1`` readings to a forecast of the ``W``-th; the
  anomaly score is the squared forecast error against the reading that
  actually arrived.  The head owns the window/model-width asymmetry: it
  asks the engine for one extra ring reading (:meth:`ring_window`) and
  slices the model's input off the front of the window (:meth:`prepare`).

A head contributes:

1. ``loss(outputs, x, y)`` — the training objective (``sim.detector``'s
   head-generic Adam loop calls it on batched model outputs).
2. ``prepare(win)`` — the **device-side** model-input view of the window
   (identity for every head except forecast), applied before the forward
   both in training and inside the engine's jitted step.
3. ``epilogue(win, out)`` — the **device-side** verdict reduction, traced
   into the engine's jitted step (sharded and unsharded): score heads reduce
   the (S, out) model outputs to an (S, 1) score **on device**, so the host
   never materializes fleet x out_width payloads.
4. ``host_verdicts(out)`` — the host-side epilogue turning the step output
   into per-stream ``(pred, prob, score, threshold)`` verdict fields.
5. ``ring_window(input_size, n_features)`` / ``model_input_size(window,
   n_features)`` — the window-geometry contract between the serving ring
   and the model input (identity-coupled for every head except forecast).

Heads are stream-local (row-wise), so the epilogue rides through
``shard_map`` untouched — the fleet mesh sees zero new collectives, and a
heterogeneous model-group fleet (``serving.grouped``) mixes heads freely.

**Threshold calibration** (every :class:`ScoreHead`) uses the *conservative*
empirical quantile (``np.quantile(..., method="higher")``): the cutoff is an
actual calibration score at or above the interpolated position, so the
realized false-positive rate **on the calibration set itself** never exceeds
``target_fpr``.  (The default linear interpolation can place the cutoff
*between* order statistics on small calibration sets, letting the empirical
FPR overshoot the target it was calibrated to.)

**Streaming recalibration** (online drift adaptation): real plants drift —
sensor recalibration, seasonal load, wear — and a threshold calibrated once,
offline, turns a calibrated FPR into an alarm flood as the benign score
distribution creeps.  Every :class:`ScoreHead` therefore also owns the
*streaming* half of its calibration contract:

* :meth:`calib_state` — a per-stream rolling ring of recently admitted
  benign-looking scores plus per-stream admission counts, shaped for the
  serving engines' device arenas (row-local, so it shards with the
  ``("data",)`` fleet mesh with zero new collectives);
* :meth:`calib_update` — the **device-side** state transition, traced into
  the engines' donated jitted step: scores at most ``headroom`` times the
  live threshold are written into their stream's ring (scores beyond it are
  treated as attacks and never poison the calibration state; the headroom
  is what lets *gradual* benign drift through the gate even when it crosses
  the threshold itself);
* :meth:`streaming_threshold` — the **host-side** re-host of
  ``recalibrate_threshold``'s score-then-quantile sequence onto that state:
  :func:`conservative_quantile` of the pooled valid ring scores at the
  head's recorded ``target_fpr``.

The engines keep a *live* threshold (seeded by the offline-calibrated one)
that tracks the streaming quantile; ``Verdict.threshold`` reports the live
value.  :meth:`calibrate` records ``target_fpr`` on the head so streaming
recalibration chases the same operating point the offline calibration chose.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def softmax_np(logits: np.ndarray) -> np.ndarray:
    """Batched-stable host softmax: subtracts the per-row max along the last
    axis before exponentiating, so rows of extreme logits (|z| ~ 1e4, the
    saturated-detector regime) never overflow ``exp``."""
    z = logits - logits.max(axis=-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


def conservative_quantile(scores: np.ndarray, target_fpr: float) -> float:
    """The ``(1 - target_fpr)`` empirical quantile, rounded UP to an actual
    order statistic (``method="higher"``), so ``mean(scores > q)`` — the
    realized FPR on the calibration scores themselves — is ≤ ``target_fpr``
    even on small calibration sets."""
    return float(np.quantile(np.asarray(scores, np.float64), 1.0 - target_fpr,
                             method="higher"))


class DetectorHead:
    """Base: the loss / device epilogue / host verdict of one workload."""

    name: str = "?"

    def loss(self, outputs: jax.Array, x: jax.Array,
             y: Optional[jax.Array]) -> jax.Array:
        """Training objective over batched model outputs."""
        raise NotImplementedError

    def metric(self, outputs: jax.Array, x: jax.Array,
               y: Optional[jax.Array]) -> jax.Array:
        """Scalar model-selection metric — greater is better (checkpoint-best
        and early stopping in the head-generic trainer key on it)."""
        raise NotImplementedError

    def validate(self, input_size: int, n_outputs: int) -> None:
        """Raise early (engine construction) if the model can't carry this
        head; the default accepts any output width."""

    def ring_window(self, input_size: int, n_features: int) -> int:
        """Ring readings per verdict window for a model of ``input_size``.
        Default: the window IS the model input (``input_size / n_features``
        readings); the forecast head asks for one extra reading (the
        prediction target)."""
        if input_size % n_features:
            raise ValueError(
                f"model input {input_size} is not a whole number of "
                f"{n_features}-feature readings")
        return input_size // n_features

    def model_input_size(self, window: int, n_features: int) -> int:
        """Model input width for a ``window``-reading ring — the inverse of
        :meth:`ring_window`, used to validate an explicit ``window=``."""
        return window * n_features

    def prepare(self, win: jax.Array) -> jax.Array:
        """Device-side model-input view of the batched ``(S, window x F)``
        window; traced into the jitted step *and* the training loop.  The
        default feeds the whole window."""
        return win

    def epilogue(self, win: jax.Array, out: jax.Array) -> jax.Array:
        """Device-side reduction from raw model outputs to the per-stream
        verdict payload; traced into the engine's jitted detector step."""
        raise NotImplementedError

    def kernel_epilogue(self) -> Optional[Tuple[str, str]]:
        """The head's in-kernel epilogue spec for the grouped megakernel
        (``serving/core.py`` single-dispatch fleets), or None when the
        epilogue cannot run in-kernel and the engine must fall back to
        per-group dispatch.  The spec is ``(payload, target)``:
        ``("logits", "none")`` passes the final activations through;
        ``("mse", "window" | "tail" | "center")`` reduces to the mean
        squared error against the whole window, its newest reading, or a
        fixed center row.  The default is None — custom heads opt in."""
        return None

    def host_verdicts(self, out: np.ndarray,
                      threshold: Optional[float] = None) -> Tuple[
            np.ndarray, Optional[np.ndarray], Optional[np.ndarray],
            Optional[float]]:
        """Step output -> (pred, prob|None, score|None, threshold|None),
        each an array over streams (threshold is one float for the fleet).
        ``threshold`` overrides the head's own calibrated cutoff — the
        engines pass their *live* (streaming-recalibrated) threshold here,
        so verdicts track drift while the head stays frozen."""
        raise NotImplementedError

    # -- IEC 61131-3 Structured Text export (repro.codegen.st) --------------
    #
    # The ST exporter asks the head for the *verdict epilogue* of the emitted
    # FUNCTION_BLOCK: the statements that turn the model-output array into
    # the PLC-side verdict variables, mirroring epilogue/host_verdicts.  The
    # writer is duck-typed (codegen.st.STWriter) so this module never imports
    # the codegen package; ``ctx`` is a codegen.st.STContext carrying the
    # array names and widths of the surrounding block.

    def st_verdict_outputs(self) -> Tuple[str, ...]:
        """Names of the VAR_OUTPUTs the head's ST epilogue produces, in
        Verdict-field order — the verification harness compares exactly
        these against the engine's verdicts."""
        raise NotImplementedError(
            f"{type(self).__name__} has no Structured Text export epilogue")

    def st_epilogue(self, w, ctx) -> None:
        """Write the verdict epilogue into an ST writer: declare the verdict
        VAR_OUTPUTs and emit the statements computing them from the model
        output array ``ctx.y`` (and the model-input view ``ctx.x``)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no Structured Text export epilogue")


@dataclasses.dataclass(frozen=True)
class ClassifierHead(DetectorHead):
    """Supervised classifier: CE loss, argmax verdict (§7's head)."""

    name: str = "classifier"

    def loss(self, outputs, x, y):
        logz = jax.scipy.special.logsumexp(outputs, axis=-1)
        gold = jnp.take_along_axis(outputs, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    def metric(self, outputs, x, y):
        return jnp.mean(jnp.argmax(outputs, axis=-1) == y)

    def epilogue(self, win, out):
        return out                      # the logits ARE the verdict payload

    def kernel_epilogue(self):
        # Pass-through logits; a final-layer softmax is masked in-kernel to
        # the group's true class count.
        return ("logits", "none")

    def host_verdicts(self, out, threshold=None):
        pred = out.argmax(axis=-1)
        prob = softmax_np(out)[np.arange(len(out)), pred]
        return pred.astype(np.int64), prob, None, None

    def st_verdict_outputs(self):
        return ("PRED", "CONF")

    def st_epilogue(self, w, ctx):
        # Argmax with strict `>` keeps the FIRST maximum — np.argmax's tie
        # rule — and the softmax probability of the argmax class collapses to
        # 1/sum(exp(y_i - max)): exp(0) = 1.0 exactly, so the winning term
        # needs no batch-varying index, and the sequential f32 sum matches
        # softmax_np for the few-class heads this exports.
        w.output("PRED", "DINT")
        w.output("CONF", "REAL")
        w.var("I", "DINT")
        w.var("BEST", "REAL")
        w.var("ESUM", "REAL")
        w.comment("verdict: argmax class + softmax confidence of that class")
        w.line(f"BEST := {ctx.y}[0];")
        w.line("PRED := 0;")
        w.line(f"FOR I := 1 TO {ctx.n_outputs - 1} DO")
        w.line(f"    IF {ctx.y}[I] > BEST THEN")
        w.line(f"        BEST := {ctx.y}[I];")
        w.line("        PRED := I;")
        w.line("    END_IF;")
        w.line("END_FOR;")
        w.line("ESUM := 0.0;")
        w.line(f"FOR I := 0 TO {ctx.n_outputs - 1} DO")
        w.line(f"    ESUM := ESUM + EXP({ctx.y}[I] - BEST);")
        w.line("END_FOR;")
        w.line("CONF := 1.0 / ESUM;")


@dataclasses.dataclass(frozen=True)
class ScoreHead(DetectorHead):
    """Base for score-vs-threshold heads (every unsupervised workload).

    Subclasses define :meth:`batch_scores` — per-window anomaly scores from
    batched model outputs — and inherit the whole training objective
    (mean score on benign windows), device epilogue ((S, 1) on-device score
    reduction), host verdict (strict ``score > threshold``) and conservative
    FPR calibration.

    ``threshold`` is None until calibrated (:meth:`calibrate` /
    the ``sim.detector`` trainers); serving requires it.  ``target_fpr`` is
    recorded by :meth:`calibrate` so streaming recalibration
    (:meth:`streaming_threshold`) chases the same false-positive operating
    point the offline calibration chose.
    """

    threshold: Optional[float] = None
    target_fpr: Optional[float] = None
    name: str = "score"

    def batch_scores(self, outputs: jax.Array, x: jax.Array) -> jax.Array:
        """Per-window anomaly scores ``(B,)`` from batched model outputs
        (``x`` is the full window batch, pre-:meth:`prepare`)."""
        raise NotImplementedError

    def loss(self, outputs, x, y):
        return jnp.mean(self.batch_scores(outputs, x))

    def metric(self, outputs, x, y):
        # Lower anomaly score on benign data is better; the trainer maximizes.
        return -self.loss(outputs, x, y)

    def validate(self, input_size: int, n_outputs: int) -> None:
        if self.threshold is None:
            raise ValueError(
                f"{type(self).__name__} has no threshold; calibrate it on "
                "held-out normal traces first (head.calibrate / the "
                "sim.detector trainers)")

    def epilogue(self, win, out):
        # On-device score reduction: (S, out) model outputs -> (S, 1) scores
        # before anything leaves the device, so a sharded fleet ships one
        # float per stream to the host rather than the full payload.
        return self.batch_scores(out, win)[:, None]

    def calibrate(self, normal_scores: np.ndarray,
                  target_fpr: float) -> "ScoreHead":
        """A new head whose threshold realizes at most ``target_fpr`` false
        positives on the given held-out *normal* window scores (conservative
        order-statistic cutoff — module docstring)."""
        if not 0.0 < target_fpr < 1.0:
            raise ValueError(f"target_fpr must be in (0, 1), got {target_fpr}")
        scores = np.asarray(normal_scores, np.float64)
        if scores.size == 0:
            raise ValueError("cannot calibrate on zero normal scores")
        return dataclasses.replace(
            self, threshold=conservative_quantile(scores, target_fpr),
            target_fpr=target_fpr)

    def host_verdicts(self, out, threshold=None):
        thr = self.threshold if threshold is None else threshold
        if thr is None:
            raise ValueError(
                f"{type(self).__name__} has no threshold; calibrate it on "
                "held-out normal traces first (head.calibrate / the "
                "sim.detector trainers)")
        score = out[:, 0] if out.ndim == 2 else out
        pred = (score > thr).astype(np.int64)
        return pred, None, score, thr

    def st_verdict_outputs(self):
        return ("PRED", "SCORE", "THRESHOLD")

    def st_score(self, w, ctx) -> None:
        """Write the statements assigning the head's anomaly score to the
        REAL output ``SCORE`` — sequential f32 accumulation, the ST-side
        contract the verification oracle replays."""
        raise NotImplementedError

    def st_epilogue(self, w, ctx):
        if self.threshold is None:
            raise ValueError(
                f"{type(self).__name__} has no threshold; calibrate before "
                "exporting to Structured Text (the cutoff is baked into the "
                "block as a constant)")
        w.output("SCORE", "REAL")
        w.output("PRED", "DINT")
        w.output("THRESHOLD", "REAL")
        # The calibrated cutoff is an actual f32 calibration score
        # (conservative_quantile returns an order statistic), so snapping to
        # f32 is exact and the strict REAL compare below decides identically
        # to the engine's float64 `score > threshold`.
        w.const("THR", "REAL", float(np.float32(self.threshold)))
        self.st_score(w, ctx)
        w.comment("verdict: strict score > calibrated threshold")
        w.line("THRESHOLD := THR;")
        w.line("IF SCORE > THR THEN")
        w.line("    PRED := 1;")
        w.line("ELSE")
        w.line("    PRED := 0;")
        w.line("END_IF;")

    # -- streaming recalibration (online drift adaptation) -----------------

    def calib_state(self, n_streams: int,
                    capacity: int) -> Tuple[jax.Array, jax.Array]:
        """Zeroed per-stream rolling calibration state: a ``(n_streams,
        capacity)`` ring of admitted scores plus ``(n_streams,)`` admission
        counts.  Row-local by construction, so the serving engines shard it
        with the ring arena (``P("data", ...)``) with zero new collectives."""
        return (jnp.zeros((n_streams, capacity), jnp.float32),
                jnp.zeros((n_streams,), jnp.int32))

    def calib_update(self, ring: jax.Array, counts: jax.Array,
                     scores: jax.Array, threshold: jax.Array,
                     headroom: float) -> Tuple[jax.Array, jax.Array]:
        """Device-side state transition, traced into the engines' jitted
        step: each stream's score is admitted into its rolling ring iff it
        is at most ``headroom`` times the live ``threshold``.  Sub-headroom
        scores are what gradual benign drift looks like (they may exceed the
        threshold itself — that excess is exactly the drift the state must
        learn); scores beyond the headroom are treated as attacks and never
        enter the calibration state, so an attacked stream cannot drag the
        fleet threshold up after itself.  Rows are independent (each stream
        writes its own ring slot), so the update rides through ``shard_map``
        untouched."""
        s = scores[:, 0] if scores.ndim == 2 else scores
        admit = s <= headroom * threshold
        pos = counts % ring.shape[1]
        rows = jnp.arange(ring.shape[0])
        ring = ring.at[rows, pos].set(jnp.where(admit, s, ring[rows, pos]))
        return ring, counts + admit.astype(counts.dtype)

    def streaming_scores(self, ring, counts) -> np.ndarray:
        """Host-side: the pooled valid scores in a gathered calibration
        state (ring slot ``j`` of a stream holds a real score iff ``j <
        count`` — below one full ring the state is exactly the admitted
        score list, after wraparound it is the trailing ``capacity``)."""
        ring = np.asarray(ring)
        counts = np.asarray(counts)
        valid = np.arange(ring.shape[1])[None, :] < counts[:, None]
        return ring[valid]

    def streaming_threshold(self, ring, counts, *,
                            min_count: int = 1) -> Optional[float]:
        """Host-side re-host of ``recalibrate_threshold``'s score-then-
        quantile sequence onto the streaming state: the conservative
        ``(1 - target_fpr)`` quantile of the pooled valid ring scores.
        Returns None (leave the live threshold alone) until ``min_count``
        scores have been admitted fleet-wide."""
        if self.target_fpr is None:
            raise ValueError(
                f"{type(self).__name__} has no target_fpr; calibrate via "
                "head.calibrate / the sim.detector trainers (or construct "
                "with target_fpr=) before streaming recalibration")
        scores = self.streaming_scores(ring, counts)
        if scores.size < max(min_count, 1):
            return None
        return conservative_quantile(scores, self.target_fpr)


@dataclasses.dataclass(frozen=True)
class ReconstructionHead(ScoreHead):
    """Unsupervised autoencoder: MSE loss on benign windows, anomaly score =
    per-window mean squared reconstruction error, verdict = score exceeding
    a threshold calibrated to ``target_fpr`` on held-out normal traces.

    ``threshold`` is None until calibrated (:meth:`calibrate` /
    ``sim.detector.train_autoencoder``); serving requires it.
    """

    name: str = "reconstruction"

    def validate(self, input_size: int, n_outputs: int) -> None:
        if n_outputs != input_size:
            raise ValueError(
                f"ReconstructionHead needs an autoencoder whose output width "
                f"({n_outputs}) equals its input width ({input_size})")
        super().validate(input_size, n_outputs)

    def batch_scores(self, outputs, x):
        return jnp.mean(jnp.square(outputs - x), axis=-1)

    def kernel_epilogue(self):
        return ("mse", "window")

    def scores(self, recon: jax.Array, x: jax.Array) -> jax.Array:
        """Per-window anomaly scores from batched reconstructions."""
        return self.batch_scores(recon, x)

    def st_score(self, w, ctx):
        w.var("I", "DINT")
        w.var("T", "REAL")
        w.comment("anomaly score: mean squared reconstruction error")
        w.line("SCORE := 0.0;")
        w.line(f"FOR I := 0 TO {ctx.n_outputs - 1} DO")
        w.line(f"    T := {ctx.y}[I] - {ctx.x}[I];")
        w.line("    SCORE := SCORE + T * T;")
        w.line("END_FOR;")
        w.line(f"SCORE := SCORE / {w.real(float(ctx.n_outputs))};")


@dataclasses.dataclass(frozen=True)
class MarginHead(ScoreHead):
    """Unsupervised one-class margin (Deep-SVDD-style): the model embeds a
    window; benign training pulls embeddings toward a fixed ``center`` (the
    mean initial embedding of benign windows — ``sim.detector.
    train_one_class`` computes it), and the anomaly score is the mean
    squared distance from it.  The calibrated ``threshold`` is the margin
    radius: scores beyond it are flagged.
    """

    center: Optional[Tuple[float, ...]] = None
    name: str = "margin"

    def _center(self) -> jax.Array:
        return jnp.asarray(self.center, jnp.float32)

    def validate(self, input_size: int, n_outputs: int) -> None:
        if self.center is None:
            raise ValueError(
                "MarginHead has no center; fit one on benign windows first "
                "(sim.detector.train_one_class)")
        if len(self.center) != n_outputs:
            raise ValueError(
                f"MarginHead center has {len(self.center)} dims but the "
                f"model embeds into {n_outputs}")
        super().validate(input_size, n_outputs)

    def batch_scores(self, outputs, x):
        return jnp.mean(jnp.square(outputs - self._center()), axis=-1)

    def kernel_epilogue(self):
        return ("mse", "center")

    def st_score(self, w, ctx):
        w.var("I", "DINT")
        w.var("T", "REAL")
        w.const("CENTER", "REAL",
                [float(np.float32(c)) for c in self.center])
        w.comment("anomaly score: mean squared distance from the benign "
                  "center")
        w.line("SCORE := 0.0;")
        w.line(f"FOR I := 0 TO {ctx.n_outputs - 1} DO")
        w.line(f"    T := {ctx.y}[I] - CENTER[I];")
        w.line("    SCORE := SCORE + T * T;")
        w.line("END_FOR;")
        w.line(f"SCORE := SCORE / {w.real(float(ctx.n_outputs))};")


@dataclasses.dataclass(frozen=True)
class ForecastHead(ScoreHead):
    """Unsupervised next-step prediction: the model maps the window's first
    ``W - 1`` readings to a forecast of the ``W``-th, and the anomaly score
    is the mean squared forecast error against the reading that actually
    arrived — physics violations surface as unforecastable transitions.

    The head owns the geometry asymmetry: the serving ring holds one more
    reading than the model consumes (:meth:`ring_window`), and
    :meth:`prepare` slices the model input off the front of each window —
    on device, inside the jitted step, for training and serving alike.
    """

    n_features: int = 2
    name: str = "forecast"

    def ring_window(self, input_size: int, n_features: int) -> int:
        if n_features != self.n_features:
            raise ValueError(
                f"ForecastHead was built for {self.n_features} features, "
                f"engine has {n_features}")
        if input_size % n_features:
            raise ValueError(
                f"forecast model input {input_size} is not a whole number "
                f"of {n_features}-feature readings")
        # One extra ring reading: the model eats W-1 readings, the W-th is
        # the forecast target.
        return input_size // n_features + 1

    def model_input_size(self, window: int, n_features: int) -> int:
        return (window - 1) * n_features

    def prepare(self, win):
        return win[..., :-self.n_features]

    def validate(self, input_size: int, n_outputs: int) -> None:
        if n_outputs != self.n_features:
            raise ValueError(
                f"ForecastHead predicts one {self.n_features}-feature "
                f"reading but the model outputs {n_outputs}")
        super().validate(input_size, n_outputs)

    def batch_scores(self, outputs, x):
        # x is the FULL window batch; the target is its last reading.
        return jnp.mean(
            jnp.square(outputs - x[..., -self.n_features:]), axis=-1)

    def kernel_epilogue(self):
        # The megakernel feeds the FULL window as x and zero-pads the model's
        # weight rows past its true input width, so prepare()'s slice is
        # subsumed by the zero-row contract; the target is the window tail.
        return ("mse", "tail")

    def st_score(self, w, ctx):
        # ctx.x is the FULL window array (the block keeps the extra ring
        # reading); the forecast target is its last reading, starting at the
        # model-input width the body consumed.
        w.var("I", "DINT")
        w.var("T", "REAL")
        w.comment("anomaly score: mean squared next-step forecast error")
        w.line("SCORE := 0.0;")
        w.line(f"FOR I := 0 TO {ctx.n_outputs - 1} DO")
        w.line(f"    T := {ctx.y}[I] - {ctx.x}[I + {ctx.in_width}];")
        w.line("    SCORE := SCORE + T * T;")
        w.line("END_FOR;")
        w.line(f"SCORE := SCORE / {w.real(float(ctx.n_outputs))};")
