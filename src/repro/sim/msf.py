"""Multi-Stage Flash desalination plant simulation + process-aware attacks.

Stand-in for the paper's MATLAB/Simulink HITL setup (§7): a reduced-order
thermal model of an MSF plant (validated against the qualitative behaviour in
Ali 2002 / Rajput 2019 that the paper builds on), a cascading PID controller
(the PLC's control task), an ADC model reproducing the quantization effects
the paper observes in Fig. 7, and the seven process-aware attack families of
the §7 dataset.

State (per 100 ms scan cycle):
  TB0  — top/initial brine temperature (°C), driven by steam flow Ws
  Wd   — distillate product flow (tons/min), a function of flash range
Control: cascading PID — outer loop holds Wd at its setpoint by adjusting the
TB0 setpoint; inner loop drives Ws to track TB0.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import msf_detector as spec

SCAN_DT = 0.1  # 100 ms scan cycle (§7)


@dataclasses.dataclass
class PlantParams:
    t_sea: float = 35.0          # seawater temperature (°C)
    tb0_init: float = 89.667     # initial brine temperature (settled)
    tau_tb: float = 60.0         # brine thermal time constant (s)
    k_steam: float = 9.5         # °C per (ton/min) steam at steady state
    k_flash: float = 0.42        # distillate yield per °C of flash range
    t_flash_min: float = 44.0    # minimum flash temperature
    recycle: float = 1.0         # recycle brine flow factor (attack target)
    reject: float = 0.0          # water-rejection disturbance (attack target)
    noise_tb0: float = 0.002     # process noise std
    noise_wd: float = 0.0005
    wd_setpoint: float = 19.18   # tons/min (paper's §7.2 mean)


def jitter_params(base: PlantParams, rel: float,
                  rng: np.random.Generator) -> PlantParams:
    """Perturb the plant's *physical* constants by a relative uniform jitter
    (never the Wd setpoint, which the operator fixes fleet-wide)."""
    if rel <= 0.0:
        return dataclasses.replace(base)

    def j(v: float) -> float:
        return float(v * (1.0 + rng.uniform(-rel, rel)))

    return dataclasses.replace(
        base,
        tau_tb=j(base.tau_tb),
        k_steam=j(base.k_steam),
        k_flash=j(base.k_flash),
        noise_tb0=j(base.noise_tb0),
        noise_wd=j(base.noise_wd),
    )


# Physical constants a benign drift may creep — jitter_params' set plus the
# environment-driven ones; never the Wd setpoint (operator-fixed).
DRIFTABLE = frozenset({"t_sea", "tau_tb", "k_steam", "k_flash",
                       "t_flash_min", "recycle", "noise_tb0", "noise_wd"})


@dataclasses.dataclass(frozen=True)
class ParamDrift:
    """Benign time-varying plant drift — ``jitter_params`` made time-varying.

    NOT an attack: labels stay 0.  This is the threshold-killer the ICS
    surveys describe — sensor recalibration, seasonal seawater temperature,
    fouling/wear — creeping the benign operating point away from where the
    detector's threshold was calibrated.

    ``shifts`` maps physical-constant names (:data:`DRIFTABLE`) to the total
    relative change reached at the end of the ramp: field ``f`` at cycle
    ``c`` is ``base.f * (1 + shift * frac(c))``, where ``frac`` ramps
    linearly from 0 at ``start`` to 1 at ``start + ramp`` and holds there.
    A dict passed as ``shifts`` is normalized to a sorted tuple of pairs so
    the dataclass stays hashable/frozen.
    """

    shifts: Tuple[Tuple[str, float], ...]
    start: int = 0
    ramp: int = 1000

    def __post_init__(self):
        s = self.shifts
        items = sorted(s.items()) if isinstance(s, dict) else list(s)
        shifts = tuple((str(k), float(v)) for k, v in items)
        if not shifts:
            raise ValueError("ParamDrift needs at least one shifted field")
        for k, v in shifts:
            if k not in DRIFTABLE:
                raise ValueError(
                    f"cannot drift {k!r}; driftable fields: "
                    f"{sorted(DRIFTABLE)}")
            if v <= -1.0:
                raise ValueError(
                    f"shift for {k!r} must be > -1 (a physical constant "
                    f"cannot drift through zero), got {v}")
        if self.ramp < 1:
            raise ValueError(f"ramp must be >= 1 cycle, got {self.ramp}")
        object.__setattr__(self, "shifts", shifts)

    def fraction(self, cycle: int) -> float:
        """Ramp progress in [0, 1] at ``cycle``."""
        if cycle <= self.start:
            return 0.0
        return min((cycle - self.start) / self.ramp, 1.0)

    def apply(self, base: PlantParams, cycle: int) -> PlantParams:
        """The drifted parameter set at ``cycle`` (``base`` if pre-onset)."""
        f = self.fraction(cycle)
        if f == 0.0:
            return base
        return dataclasses.replace(
            base, **{k: getattr(base, k) * (1.0 + v * f)
                     for k, v in self.shifts})


@dataclasses.dataclass
class PIDGains:
    kp: float
    ki: float
    kd: float
    out_min: float
    out_max: float


class PID:
    def __init__(self, g: PIDGains):
        self.g = g
        self.i = 0.0
        self.prev_err: Optional[float] = None

    def step(self, err: float, dt: float) -> float:
        self.i += err * dt
        d = 0.0 if self.prev_err is None else (err - self.prev_err) / dt
        self.prev_err = err
        out = self.g.kp * err + self.g.ki * self.i + self.g.kd * d
        return float(np.clip(out, self.g.out_min, self.g.out_max))


class CascadePID:
    """Outer: Wd -> TB0 setpoint.  Inner: TB0 -> steam flow Ws.

    Integrators are warm-started at the plant's steady state (the paper's
    HITL runs likewise start from an initialized desalination process, §7.2)
    so traces begin settled rather than with a cold-start transient."""

    def __init__(self, warm_start: bool = True):
        self.outer = PID(PIDGains(kp=8.0, ki=0.15, kd=0.0,
                                  out_min=70.0, out_max=110.0))
        self.inner = PID(PIDGains(kp=0.6, ki=0.05, kd=0.0,
                                  out_min=0.0, out_max=25.0))
        if warm_start:
            # steady state: Wd*=19.18 -> TB0*=89.667 -> Ws*=5.7544
            self.outer.i = 89.667 / self.outer.g.ki
            self.inner.i = 5.7544 / self.inner.g.ki

    def step(self, wd_meas: float, tb0_meas: float, wd_sp: float,
             dt: float = SCAN_DT) -> float:
        tb0_sp = self.outer.step(wd_sp - wd_meas, dt)
        return self.inner.step(tb0_sp - tb0_meas, dt)


def adc(value: float, lo: float, hi: float, bits: int = 12) -> float:
    """PLC ADC model: clamp + uniform quantization (Fig. 7 step artefacts)."""
    levels = (1 << bits) - 1
    x = np.clip((value - lo) / (hi - lo), 0.0, 1.0)
    return lo + np.round(x * levels) / levels * (hi - lo)


# ---------------------------------------------------------------------------
# Attacks (7 families, §7): actuator tampering + false data injection.
# Each returns (ws_eff, params_override, sensor_bias) per cycle.
# ---------------------------------------------------------------------------

AttackFn = Callable[[int, float], Tuple[float, Dict[str, float], Tuple[float, float]]]

ATTACK_NAMES: Dict[int, str] = {
    1: "steam_scale", 2: "recycle_cut", 3: "reject_boost", 4: "tb0_fdi",
    5: "wd_fdi", 6: "oscillate", 7: "ramp",
}


def make_attack(attack_id: int, intensity: float = 1.0) -> AttackFn:
    """One attack family, scaled by ``intensity`` (1.0 = the §7 magnitudes).

    Returns function(cycle_in_attack, ws_cmd) -> (ws_eff, params_override,
    (tb0_bias, wd_bias)).  id 0 is reserved for 'no attack'.
    """
    i = intensity

    def a1_steam_scale(t, ws):      # actuator: steam valve scaled down
        return ws * (1.0 - 0.45 * i), {}, (0.0, 0.0)

    def a2_recycle_cut(t, ws):      # actuator: recycle brine reduced
        return ws, {"recycle": 1.0 - 0.38 * i}, (0.0, 0.0)

    def a3_reject_boost(t, ws):     # actuator: water rejection increased
        return ws, {"reject": 6.5 * i}, (0.0, 0.0)

    def a4_tb0_fdi(t, ws):          # sensor FDI: TB0 reads high
        return ws, {}, (3.5 * i, 0.0)

    def a5_wd_fdi(t, ws):           # sensor FDI: Wd reads high
        return ws, {}, (0.0, 0.9 * i)

    def a6_oscillate(t, ws):        # actuator: oscillatory steam valve
        return ws * (1.0 + 0.45 * i * np.sin(2 * np.pi * t / 80.0)), {}, (0.0, 0.0)

    def a7_ramp(t, ws):             # stealthy ramp on recycle efficiency
        frac = min(t / 1200.0, 1.0)
        return ws, {"recycle": 1.0 - 0.35 * i * frac}, (0.0, 0.0)

    fns = {1: a1_steam_scale, 2: a2_recycle_cut, 3: a3_reject_boost,
           4: a4_tb0_fdi, 5: a5_wd_fdi, 6: a6_oscillate, 7: a7_ramp}
    if attack_id not in fns:
        raise ValueError(f"unknown attack id {attack_id}; pick from 1..7")
    return fns[attack_id]


def make_attacks(rng: Optional[np.random.Generator] = None,
                 intensity: float = 1.0) -> Dict[int, AttackFn]:
    """Attack id -> AttackFn for all seven families (§7 magnitudes)."""
    return {k: make_attack(k, intensity) for k in ATTACK_NAMES}


@dataclasses.dataclass(frozen=True)
class AttackEvent:
    """One scheduled attack: family x onset x duration x intensity.

    ``duration=None`` means the attack persists to the end of the run.  The
    per-cycle attack clock (what ``AttackFn`` sees) restarts at ``start``.
    """

    attack_id: int
    start: int
    duration: Optional[int] = None
    intensity: float = 1.0

    def active(self, cycle: int) -> bool:
        if cycle < self.start:
            return False
        return self.duration is None or cycle < self.start + self.duration


# ---------------------------------------------------------------------------
# Plant
# ---------------------------------------------------------------------------


class MSFPlant:
    """Reduced-order MSF dynamics stepped at the scan cycle."""

    def __init__(self, params: PlantParams, seed: int = 0):
        self.p = dataclasses.replace(params)
        self.base = params
        self.tb0 = params.tb0_init
        self.rng = np.random.default_rng(seed)

    def step(self, ws: float, dt: float = SCAN_DT) -> Tuple[float, float]:
        """Advance one cycle with steam flow `ws`; returns true (TB0, Wd)."""
        p = self.p
        t_target = p.t_sea - p.reject + p.k_steam * ws
        self.tb0 += (t_target - self.tb0) * dt / p.tau_tb
        self.tb0 += self.rng.normal(0.0, p.noise_tb0)
        flash_range = max(self.tb0 - p.t_flash_min, 0.0)
        wd = p.k_flash * flash_range * p.recycle
        wd += self.rng.normal(0.0, p.noise_wd)
        return self.tb0, wd

    def apply_overrides(self, overrides: Dict[str, float],
                        base: Optional[PlantParams] = None) -> None:
        """Rebuild the effective params from ``base`` (default: the
        construction-time params — a drifting stream passes the drifted set)
        plus the attack's overrides."""
        base = self.base if base is None else base
        self.p = dataclasses.replace(base, **overrides) if overrides else \
            dataclasses.replace(base)


@dataclasses.dataclass
class SimTrace:
    tb0_meas: np.ndarray     # what the PLC ADC read
    wd_meas: np.ndarray
    tb0_true: np.ndarray     # simulation ground truth
    wd_true: np.ndarray
    ws_cmd: np.ndarray
    label: np.ndarray        # 0 normal, k = attack id


@dataclasses.dataclass
class CycleReading:
    """One scan cycle's observables from a :class:`PlantStream`."""

    tb0_meas: float
    wd_meas: float
    tb0_true: float
    wd_true: float
    ws_cmd: float
    label: int               # 0 normal, k = attack id active this cycle


class PlantStream:
    """One plant + cascading PID + attack schedule, stepped one scan cycle at
    a time — the streaming core behind both :func:`simulate` (offline traces)
    and the fleet serving path (`repro.serving.streams.StreamEngine`).

    ``events`` is a sequence of :class:`AttackEvent`; when several are active
    at once the earliest-listed one wins (no superposition — one adversary at
    the controls at a time).  ``drift`` is an optional :class:`ParamDrift`
    creeping the plant's physical constants over time — benign (labels stay
    0) and composable with attacks: the attack's parameter overrides apply
    on top of the drifted base.
    """

    def __init__(self, params: Optional[PlantParams] = None, *,
                 events: Sequence[AttackEvent] = (), seed: int = 0,
                 name: str = "", drift: Optional[ParamDrift] = None):
        self.params = params or PlantParams()
        self.plant = MSFPlant(self.params, seed=seed)
        self.pid = CascadePID()
        self.events = tuple(events)
        self._fns = [make_attack(e.attack_id, e.intensity) for e in self.events]
        self.name = name
        self.drift = drift
        self.cycle = 0
        # settle readings at the operating point before the loop
        self.tb0_true = self.params.tb0_init
        self.wd_true = self.params.wd_setpoint

    def _active(self, cycle: int) -> Tuple[Optional[AttackEvent], Optional[AttackFn]]:
        for e, fn in zip(self.events, self._fns):
            if e.active(cycle):
                return e, fn
        return None, None

    def step(self) -> CycleReading:
        """Advance one scan cycle: sense -> control -> actuate."""
        cycle = self.cycle
        event, fn = self._active(cycle)

        # -- sense (through the ADC, with FDI biases if attacked)
        bias_tb0, bias_wd = 0.0, 0.0
        if event is not None:
            _, _, (bias_tb0, bias_wd) = fn(cycle - event.start, 0.0)
        tb0_meas = adc(self.tb0_true + bias_tb0, 40.0, 120.0)
        wd_meas = adc(self.wd_true + bias_wd, 0.0, 40.0)

        # -- control (the PLC's primary task)
        ws = self.pid.step(wd_meas, tb0_meas, self.params.wd_setpoint)

        # -- actuate (attack may tamper with actuators / plant params;
        #    benign drift creeps the base the overrides apply on top of)
        overrides: Dict[str, float] = {}
        ws_eff = ws
        if event is not None:
            ws_eff, overrides, _ = fn(cycle - event.start, ws)
        base = self.params if self.drift is None \
            else self.drift.apply(self.params, cycle)
        self.plant.apply_overrides(overrides, base=base)
        self.tb0_true, self.wd_true = self.plant.step(ws_eff)

        self.cycle += 1
        return CycleReading(
            tb0_meas=tb0_meas, wd_meas=wd_meas,
            tb0_true=self.tb0_true, wd_true=self.wd_true,
            ws_cmd=ws, label=event.attack_id if event is not None else 0,
        )


def simulate(
    n_cycles: int,
    *,
    attack_id: int = 0,
    attack_start: Optional[int] = None,
    seed: int = 0,
    defense_hook: Optional[Callable[[int, np.ndarray], None]] = None,
    events: Optional[Sequence[AttackEvent]] = None,
    params: Optional[PlantParams] = None,
    drift: Optional[ParamDrift] = None,
) -> SimTrace:
    """Run the closed loop for n_cycles; optionally inject attacks.

    ``attack_id``/``attack_start`` keep the original single-attack interface;
    ``events`` takes a full :class:`AttackEvent` schedule (mutually exclusive
    with the former).  ``drift`` applies benign parameter drift.
    """
    if events is None:
        events = ([AttackEvent(attack_id, attack_start)]
                  if attack_id != 0 and attack_start is not None else [])
    elif attack_id != 0 or attack_start is not None:
        raise ValueError("pass either attack_id/attack_start or events, not both")
    stream = PlantStream(params, events=events, seed=seed, drift=drift)

    out = {k: np.zeros(n_cycles) for k in
           ("tb0_meas", "wd_meas", "tb0_true", "wd_true", "ws_cmd", "label")}

    for cycle in range(n_cycles):
        r = stream.step()
        if defense_hook is not None:
            defense_hook(cycle, np.array([r.tb0_meas, r.wd_meas], np.float32))
        out["tb0_meas"][cycle] = r.tb0_meas
        out["wd_meas"][cycle] = r.wd_meas
        out["tb0_true"][cycle] = r.tb0_true
        out["wd_true"][cycle] = r.wd_true
        out["ws_cmd"][cycle] = r.ws_cmd
        out["label"][cycle] = r.label

    return SimTrace(**{k: v for k, v in out.items()})


# ---------------------------------------------------------------------------
# Dataset formation (§7: 2 features x 10 readings/s x 20 s = 400 inputs)
# ---------------------------------------------------------------------------


def build_dataset(
    *,
    window: int = 200,
    stride: int = 10,
    normal_cycles: int = 42_000,
    attack_cycles: int = 5_700,
    seed: int = 0,
    attack_param_scale: float = 1.0,
    jitter: float = 0.0,
    jitter_plants: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Windows of (TB0, Wd) readings -> binary labels (attack in window tail).

    Defaults approximate the paper's 22h45m dataset proportions scaled down;
    `attack_param_scale` perturbs attack magnitudes so evaluation can use
    parameters unseen in training (§7.1).  ``jitter``/``jitter_plants`` add
    normal traces from physically-jittered plants so a fleet-serving detector
    (heterogeneous plants, see ``repro.sim.scenarios``) learns that per-plant
    operating-point spread is benign.
    """
    xs: List[np.ndarray] = []
    ys: List[int] = []

    def add_windows(trace: SimTrace):
        feats = np.stack([trace.tb0_meas, trace.wd_meas], axis=1).astype(np.float32)
        # standardize around the nominal operating point (the PLC-side
        # normalization the paper's porting flow bakes into data collection)
        feats -= np.asarray(spec.NORM_MEAN, np.float32)
        feats /= np.asarray(spec.NORM_STD, np.float32)
        for start in range(0, len(feats) - window, stride):
            w = feats[start:start + window]
            lab = trace.label[start:start + window]
            xs.append(w.reshape(-1))
            ys.append(int(lab[-window // 4:].max() > 0))

    add_windows(simulate(normal_cycles, seed=seed))
    if jitter > 0.0 and jitter_plants > 0:
        per_plant = max(normal_cycles // jitter_plants, window + stride)
        for j in range(jitter_plants):
            p = jitter_params(PlantParams(), jitter,
                              np.random.default_rng(seed + 600 + j))
            add_windows(simulate(per_plant, seed=seed + 300 + j, params=p))
    for attack_id in range(1, 8):
        tr = simulate(attack_cycles, attack_id=attack_id,
                      attack_start=attack_cycles // 5, seed=seed + 10 + attack_id)
        if attack_param_scale != 1.0:
            pass  # scale applied through seeds; kept for interface clarity
        add_windows(tr)

    x = np.stack(xs)
    y = np.asarray(ys, np.int64)
    rng = np.random.default_rng(seed + 99)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]
