"""Multi-Stage Flash desalination plant simulation + process-aware attacks.

Stand-in for the paper's MATLAB/Simulink HITL setup (§7): a reduced-order
thermal model of an MSF plant (validated against the qualitative behaviour in
Ali 2002 / Rajput 2019 that the paper builds on), a cascading PID controller
(the PLC's control task), an ADC model reproducing the quantization effects
the paper observes in Fig. 7, and the seven process-aware attack families of
the §7 dataset.

State (per 100 ms scan cycle):
  TB0  — top/initial brine temperature (°C), driven by steam flow Ws
  Wd   — distillate product flow (tons/min), a function of flash range
Control: cascading PID — outer loop holds Wd at its setpoint by adjusting the
TB0 setpoint; inner loop drives Ws to track TB0.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

SCAN_DT = 0.1  # 100 ms scan cycle (§7)


@dataclasses.dataclass
class PlantParams:
    t_sea: float = 35.0          # seawater temperature (°C)
    tb0_init: float = 89.667     # initial brine temperature (settled)
    tau_tb: float = 60.0         # brine thermal time constant (s)
    k_steam: float = 9.5         # °C per (ton/min) steam at steady state
    k_flash: float = 0.42        # distillate yield per °C of flash range
    t_flash_min: float = 44.0    # minimum flash temperature
    recycle: float = 1.0         # recycle brine flow factor (attack target)
    reject: float = 0.0          # water-rejection disturbance (attack target)
    noise_tb0: float = 0.002     # process noise std
    noise_wd: float = 0.0005
    wd_setpoint: float = 19.18   # tons/min (paper's §7.2 mean)


@dataclasses.dataclass
class PIDGains:
    kp: float
    ki: float
    kd: float
    out_min: float
    out_max: float


class PID:
    def __init__(self, g: PIDGains):
        self.g = g
        self.i = 0.0
        self.prev_err: Optional[float] = None

    def step(self, err: float, dt: float) -> float:
        self.i += err * dt
        d = 0.0 if self.prev_err is None else (err - self.prev_err) / dt
        self.prev_err = err
        out = self.g.kp * err + self.g.ki * self.i + self.g.kd * d
        return float(np.clip(out, self.g.out_min, self.g.out_max))


class CascadePID:
    """Outer: Wd -> TB0 setpoint.  Inner: TB0 -> steam flow Ws.

    Integrators are warm-started at the plant's steady state (the paper's
    HITL runs likewise start from an initialized desalination process, §7.2)
    so traces begin settled rather than with a cold-start transient."""

    def __init__(self, warm_start: bool = True):
        self.outer = PID(PIDGains(kp=8.0, ki=0.15, kd=0.0,
                                  out_min=70.0, out_max=110.0))
        self.inner = PID(PIDGains(kp=0.6, ki=0.05, kd=0.0,
                                  out_min=0.0, out_max=25.0))
        if warm_start:
            # steady state: Wd*=19.18 -> TB0*=89.667 -> Ws*=5.7544
            self.outer.i = 89.667 / self.outer.g.ki
            self.inner.i = 5.7544 / self.inner.g.ki

    def step(self, wd_meas: float, tb0_meas: float, wd_sp: float,
             dt: float = SCAN_DT) -> float:
        tb0_sp = self.outer.step(wd_sp - wd_meas, dt)
        return self.inner.step(tb0_sp - tb0_meas, dt)


def adc(value: float, lo: float, hi: float, bits: int = 12) -> float:
    """PLC ADC model: clamp + uniform quantization (Fig. 7 step artefacts)."""
    levels = (1 << bits) - 1
    x = np.clip((value - lo) / (hi - lo), 0.0, 1.0)
    return lo + np.round(x * levels) / levels * (hi - lo)


# ---------------------------------------------------------------------------
# Attacks (7 families, §7): actuator tampering + false data injection.
# Each returns (ws_eff, params_override, sensor_bias) per cycle.
# ---------------------------------------------------------------------------

AttackFn = Callable[[int, float], Tuple[float, Dict[str, float], Tuple[float, float]]]


def make_attacks(rng: np.random.Generator) -> Dict[int, AttackFn]:
    """Attack id -> function(cycle_in_attack, ws_cmd) -> effects.
    id 0 is reserved for 'no attack'."""

    def a1_steam_scale(t, ws):      # actuator: steam valve scaled down
        return ws * 0.55, {}, (0.0, 0.0)

    def a2_recycle_cut(t, ws):      # actuator: recycle brine reduced
        return ws, {"recycle": 0.62}, (0.0, 0.0)

    def a3_reject_boost(t, ws):     # actuator: water rejection increased
        return ws, {"reject": 6.5}, (0.0, 0.0)

    def a4_tb0_fdi(t, ws):          # sensor FDI: TB0 reads high
        return ws, {}, (3.5, 0.0)

    def a5_wd_fdi(t, ws):           # sensor FDI: Wd reads high
        return ws, {}, (0.0, 0.9)

    def a6_oscillate(t, ws):        # actuator: oscillatory steam valve
        return ws * (1.0 + 0.45 * np.sin(2 * np.pi * t / 80.0)), {}, (0.0, 0.0)

    def a7_ramp(t, ws):             # stealthy ramp on recycle efficiency
        frac = min(t / 1200.0, 1.0)
        return ws, {"recycle": 1.0 - 0.35 * frac}, (0.0, 0.0)

    return {1: a1_steam_scale, 2: a2_recycle_cut, 3: a3_reject_boost,
            4: a4_tb0_fdi, 5: a5_wd_fdi, 6: a6_oscillate, 7: a7_ramp}


# ---------------------------------------------------------------------------
# Plant
# ---------------------------------------------------------------------------


class MSFPlant:
    """Reduced-order MSF dynamics stepped at the scan cycle."""

    def __init__(self, params: PlantParams, seed: int = 0):
        self.p = dataclasses.replace(params)
        self.base = params
        self.tb0 = params.tb0_init
        self.rng = np.random.default_rng(seed)

    def step(self, ws: float, dt: float = SCAN_DT) -> Tuple[float, float]:
        """Advance one cycle with steam flow `ws`; returns true (TB0, Wd)."""
        p = self.p
        t_target = p.t_sea - p.reject + p.k_steam * ws
        self.tb0 += (t_target - self.tb0) * dt / p.tau_tb
        self.tb0 += self.rng.normal(0.0, p.noise_tb0)
        flash_range = max(self.tb0 - p.t_flash_min, 0.0)
        wd = p.k_flash * flash_range * p.recycle
        wd += self.rng.normal(0.0, p.noise_wd)
        return self.tb0, wd

    def apply_overrides(self, overrides: Dict[str, float]) -> None:
        self.p = dataclasses.replace(self.base, **overrides) if overrides else \
            dataclasses.replace(self.base)


@dataclasses.dataclass
class SimTrace:
    tb0_meas: np.ndarray     # what the PLC ADC read
    wd_meas: np.ndarray
    tb0_true: np.ndarray     # simulation ground truth
    wd_true: np.ndarray
    ws_cmd: np.ndarray
    label: np.ndarray        # 0 normal, k = attack id


def simulate(
    n_cycles: int,
    *,
    attack_id: int = 0,
    attack_start: Optional[int] = None,
    seed: int = 0,
    defense_hook: Optional[Callable[[int, np.ndarray], None]] = None,
) -> SimTrace:
    """Run the closed loop for n_cycles; optionally inject one attack."""
    plant = MSFPlant(PlantParams(), seed=seed)
    pid = CascadePID()
    attacks = make_attacks(np.random.default_rng(seed + 1))
    sp = plant.base.wd_setpoint

    # settle readings at the operating point before the loop
    tb0_true, wd_true = plant.base.tb0_init, sp

    out = {k: np.zeros(n_cycles) for k in
           ("tb0_meas", "wd_meas", "tb0_true", "wd_true", "ws_cmd", "label")}

    for cycle in range(n_cycles):
        under_attack = (
            attack_id != 0 and attack_start is not None and cycle >= attack_start
        )
        # -- sense (through the ADC, with FDI biases if attacked)
        bias_tb0, bias_wd = 0.0, 0.0
        if under_attack:
            _, _, (bias_tb0, bias_wd) = attacks[attack_id](cycle - attack_start, 0.0)
        tb0_meas = adc(tb0_true + bias_tb0, 40.0, 120.0)
        wd_meas = adc(wd_true + bias_wd, 0.0, 40.0)

        # -- control (the PLC's primary task)
        ws = pid.step(wd_meas, tb0_meas, sp)

        # -- actuate (attack may tamper with actuators / plant params)
        overrides: Dict[str, float] = {}
        ws_eff = ws
        if under_attack:
            ws_eff, overrides, _ = attacks[attack_id](cycle - attack_start, ws)
        plant.apply_overrides(overrides)
        tb0_true, wd_true = plant.step(ws_eff)

        if defense_hook is not None:
            defense_hook(cycle, np.array([tb0_meas, wd_meas], np.float32))

        out["tb0_meas"][cycle] = tb0_meas
        out["wd_meas"][cycle] = wd_meas
        out["tb0_true"][cycle] = tb0_true
        out["wd_true"][cycle] = wd_true
        out["ws_cmd"][cycle] = ws
        out["label"][cycle] = attack_id if under_attack else 0

    return SimTrace(**{k: v for k, v in out.items()})


# ---------------------------------------------------------------------------
# Dataset formation (§7: 2 features x 10 readings/s x 20 s = 400 inputs)
# ---------------------------------------------------------------------------


def build_dataset(
    *,
    window: int = 200,
    stride: int = 10,
    normal_cycles: int = 42_000,
    attack_cycles: int = 5_700,
    seed: int = 0,
    attack_param_scale: float = 1.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Windows of (TB0, Wd) readings -> binary labels (attack in window tail).

    Defaults approximate the paper's 22h45m dataset proportions scaled down;
    `attack_param_scale` perturbs attack magnitudes so evaluation can use
    parameters unseen in training (§7.1).
    """
    xs: List[np.ndarray] = []
    ys: List[int] = []

    def add_windows(trace: SimTrace):
        feats = np.stack([trace.tb0_meas, trace.wd_meas], axis=1).astype(np.float32)
        # standardize around the nominal operating point (the PLC-side
        # normalization the paper's porting flow bakes into data collection)
        feats[:, 0] = (feats[:, 0] - 89.6) / 2.0
        feats[:, 1] = (feats[:, 1] - 19.18) / 0.5
        for start in range(0, len(feats) - window, stride):
            w = feats[start:start + window]
            lab = trace.label[start:start + window]
            xs.append(w.reshape(-1))
            ys.append(int(lab[-window // 4:].max() > 0))

    add_windows(simulate(normal_cycles, seed=seed))
    for attack_id in range(1, 8):
        tr = simulate(attack_cycles, attack_id=attack_id,
                      attack_start=attack_cycles // 5, seed=seed + 10 + attack_id)
        if attack_param_scale != 1.0:
            pass  # scale applied through seeds; kept for interface clarity
        add_windows(tr)

    x = np.stack(xs)
    y = np.asarray(ys, np.int64)
    rng = np.random.default_rng(seed + 99)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]
