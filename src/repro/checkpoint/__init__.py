"""Checkpointing: save/restore parameter + optimizer pytrees.

The paper moves weights as raw binary files (BINARR/ARRBIN §4.3).  We keep
that spirit — each leaf is a raw ``.npy`` under a directory keyed by its
pytree path — plus a manifest with shapes/dtypes so restore can validate, and
step-numbered directories with an atomic 'latest' marker for crash safety.
"""

from repro.checkpoint.npz import latest_step, restore, save

__all__ = ["save", "restore", "latest_step"]
