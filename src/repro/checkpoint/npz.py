"""Filesystem checkpoint format: one .npy per leaf + manifest, atomic latest."""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out, treedef


def save(directory: str, step: int, tree: Any) -> str:
    """Write a checkpoint for `step`; returns the checkpoint directory."""
    ckpt = os.path.join(directory, f"step_{step:08d}")
    tmp = ckpt + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    manifest = {}
    for key, leaf in flat:
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".npy"
        dtype_name = str(arr.dtype)
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bfloat16, ...): store
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        np.save(os.path.join(tmp, fname), arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": dtype_name}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    if os.path.exists(ckpt):
        raise FileExistsError(ckpt)
    os.rename(tmp, ckpt)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return ckpt


def latest_step(directory: str) -> Optional[int]:
    marker = os.path.join(directory, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        return int(f.read().strip())


def restore(directory: str, tree_like: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of `tree_like` (shapes/dtypes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    ckpt = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]

    flat, treedef = _flatten_with_paths(tree_like)
    leaves = []
    for key, like in flat:
        meta = manifest.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(ckpt, meta["file"]))
        import ml_dtypes  # registers bfloat16 & friends with numpy
        want_dtype = np.dtype(meta["dtype"])
        if arr.dtype != want_dtype:         # bit-stored ml_dtypes round-trip
            arr = arr.view(want_dtype)
        want_shape = tuple(like.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: shape {arr.shape} != expected {want_shape}")
        leaves.append(jax.numpy.asarray(arr).astype(like.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
