"""Data pipeline: deterministic synthetic token streams for LM training plus
the host-side batching machinery.

The paper's data path is ARRBIN/BINARR binary files recorded on the PLC
(§4.3); `repro.core.porting` reproduces those.  For the large-architecture
training stack we provide a self-contained, seeded token source (Zipfian
unigram mixture with short-range Markov structure so the loss has learnable
signal), an on-disk binary shard format using the same ARRBIN layout, and an
iterator yielding ready-to-shard global batches.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, Optional, Sequence

import numpy as np

from repro.core.porting import arrbin, binarr


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    markov_order: int = 1
    markov_weight: float = 0.7   # P(next = f(prev)) — learnable structure


class SyntheticLM:
    """Seeded synthetic LM stream: mixture of a Zipfian unigram draw and a
    deterministic per-token successor (so a model can reduce loss below the
    unigram entropy — used by the integration tests/examples)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        # fixed random successor table: the learnable structure
        table_rng = np.random.default_rng(cfg.seed + 1)
        self._succ = table_rng.integers(0, cfg.vocab, size=cfg.vocab)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._unigram = probs / probs.sum()

    def _sample_row(self, length: int) -> np.ndarray:
        out = np.empty(length + 1, np.int32)
        out[0] = self._rng.choice(self.cfg.vocab, p=self._unigram)
        use_succ = self._rng.random(length) < self.cfg.markov_weight
        fresh = self._rng.choice(self.cfg.vocab, size=length, p=self._unigram)
        for i in range(length):
            out[i + 1] = self._succ[out[i]] if use_succ[i] else fresh[i]
        return out

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        b, s = self.cfg.global_batch, self.cfg.seq_len
        while True:
            rows = np.stack([self._sample_row(s) for _ in range(b)])
            yield {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


# ---------------------------------------------------------------------------
# Binary shard format (ARRBIN layout + sidecar metadata, §4.3 style)
# ---------------------------------------------------------------------------


def write_shard(path: str, tokens: np.ndarray) -> None:
    arrbin(path, tokens.astype(np.int32))
    with open(path + ".meta", "w") as f:
        f.write(f"int32 {tokens.shape[0]} {tokens.shape[1]}\n")


def read_shard(path: str) -> np.ndarray:
    with open(path + ".meta") as f:
        dtype, rows, cols = f.read().split()
    return binarr(path, dtype, (int(rows), int(cols)))


class ShardedDataset:
    """Round-robin reader over binary shards (deterministic, restartable)."""

    def __init__(self, paths: Sequence[str], global_batch: int):
        if not paths:
            raise ValueError("no shards")
        self.paths = list(paths)
        self.global_batch = global_batch

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            shard = read_shard(self.paths[step % len(self.paths)])
            n = shard.shape[0]
            idx = (np.arange(self.global_batch) + step * self.global_batch) % n
            rows = shard[idx]
            yield {"tokens": rows[:, :-1], "labels": rows[:, 1:]}
            step += 1
