from repro.data.pipeline import DataConfig, ShardedDataset, SyntheticLM, read_shard, write_shard

__all__ = ["DataConfig", "ShardedDataset", "SyntheticLM", "read_shard", "write_shard"]
